//! Streaming data plane: chunked ingestion of `X ∈ R^{N×T}`.
//!
//! Picard's cost is dominated by Θ(N²T) sweeps over T-long recordings, and
//! real recordings do not arrive as one in-memory JSON matrix. This module
//! is the layer between I/O and the solver:
//!
//! - [`DataSource`] — a resettable iterator over **column chunks** of `X`
//!   (signals in rows, samples in columns). Two passes are all the
//!   pipeline ever needs: one for moments, one for whitening.
//! - [`MemSource`] — adapter over an in-memory [`Mat`].
//! - [`BinSource`] / [`BinWriter`] — the `FICA1` raw little-endian f64
//!   binary format (24-byte validated header, sample-major frames).
//! - [`CsvSource`] / [`CsvWriter`] — one sample per line, one field per
//!   signal.
//! - [`StreamingStats`] — one-pass mean + covariance accumulator, so the
//!   whitener is computed without materializing the raw matrix.
//!
//! Every file implementation is fail-closed: bad magic, length lies,
//! ragged rows, unparsable or non-finite values are typed [`IcaError`]s,
//! never panics. In-memory adapters trust their caller (the estimator
//! validates finiteness once, in `preprocess_source`).

mod bin;
mod csv;
mod stats;

pub use bin::{write_bin, BinSource, BinWriter, BIN_MAGIC};
pub use csv::{write_csv, CsvSource, CsvWriter};
pub use stats::{MomentPartial, MomentSnapshot, StreamingStats};

use crate::error::IcaError;
use crate::linalg::Mat;
use crate::util::{read_matrix_json, write_matrix_json};
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

/// Default number of sample columns per chunk on the streaming paths.
///
/// Large enough that the per-chunk matmuls amortize dispatch, small enough
/// that a chunk of a wide recording stays cache- and memory-friendly
/// (N=64 ⇒ ~4 MB per chunk).
pub const DEFAULT_CHUNK_COLS: usize = 8192;

/// A resettable producer of column chunks of a fixed-shape matrix
/// `X ∈ R^{N×T}` (signals in rows, samples in columns).
///
/// The contract mirrors what the two-pass preprocessing pipeline needs:
/// dimensions are known up front, [`DataSource::reset`] rewinds to the
/// first sample, and [`DataSource::next_chunk`] yields `X[:, p..p+c]`
/// with `1 <= c <= max_cols` until the stream is exhausted.
pub trait DataSource {
    /// Number of signals N (rows of `X`).
    fn rows(&self) -> usize;

    /// Number of samples T (columns of `X`).
    fn cols(&self) -> usize;

    /// Rewind to the first sample.
    fn reset(&mut self) -> Result<(), IcaError>;

    /// The next column chunk (`N × c`, `1 <= c <= max_cols.max(1)`), or
    /// `None` once all T samples have been yielded since the last reset.
    fn next_chunk(&mut self, max_cols: usize) -> Result<Option<Mat>, IcaError>;

    /// Skip up to `cols` columns without materializing them, returning
    /// how many were actually skipped (fewer only when the stream ends).
    /// Default: read and discard; seekable sources override with a seek
    /// (the out-of-core `grad_batch` path relies on this to avoid
    /// decoding data outside the requested sample range).
    fn skip_cols(&mut self, cols: usize) -> Result<usize, IcaError> {
        let mut skipped = 0usize;
        while skipped < cols {
            match self.next_chunk(cols - skipped)? {
                Some(chunk) => skipped += chunk.cols(),
                None => break,
            }
        }
        Ok(skipped)
    }

    /// Whether every yielded value is already guaranteed finite (file
    /// sources reject NaN/∞ while parsing). When `true` the pipeline
    /// skips its own O(N·T) finiteness scan.
    fn validates_finite(&self) -> bool {
        false
    }

    /// Human-readable description of the source for error messages.
    fn label(&self) -> String;
}

/// Copy out the next column chunk `x[:, pos..pos+c]` (shared by the
/// in-memory source adapters).
fn mat_chunk(x: &Mat, pos: usize, max_cols: usize) -> Option<Mat> {
    if pos >= x.cols() {
        return None;
    }
    let c = max_cols.max(1).min(x.cols() - pos);
    Some(Mat::from_fn(x.rows(), c, |i, j| x[(i, pos + j)]))
}

/// In-memory [`DataSource`] over a [`Mat`] (the trusted adapter: data
/// already in memory is validated by the pipeline, not re-parsed here).
pub struct MemSource {
    x: Mat,
    pos: usize,
    label: String,
}

impl MemSource {
    /// Source over `x`, labeled `"memory"` in error messages.
    pub fn new(x: Mat) -> Self {
        Self::with_label(x, "memory")
    }

    /// Source over `x` with a custom error-message label (e.g. the path
    /// a JSON matrix was loaded from).
    pub fn with_label(x: Mat, label: impl Into<String>) -> Self {
        Self { x, pos: 0, label: label.into() }
    }

    /// Borrow the underlying matrix.
    pub fn data(&self) -> &Mat {
        &self.x
    }
}

impl DataSource for MemSource {
    fn rows(&self) -> usize {
        self.x.rows()
    }

    fn cols(&self) -> usize {
        self.x.cols()
    }

    fn reset(&mut self) -> Result<(), IcaError> {
        self.pos = 0;
        Ok(())
    }

    fn next_chunk(&mut self, max_cols: usize) -> Result<Option<Mat>, IcaError> {
        let chunk = mat_chunk(&self.x, self.pos, max_cols);
        if let Some(c) = &chunk {
            self.pos += c.cols();
        }
        Ok(chunk)
    }

    fn skip_cols(&mut self, cols: usize) -> Result<usize, IcaError> {
        let skipped = cols.min(self.x.cols() - self.pos);
        self.pos += skipped;
        Ok(skipped)
    }

    fn label(&self) -> String {
        self.label.clone()
    }
}

/// Like [`MemSource`], but borrowing the matrix — the adapter
/// [`crate::estimator::Picard::fit`] uses for its out-of-core path, where
/// cloning the caller's raw `N×T` data would defeat the point.
pub struct MatSource<'a> {
    x: &'a Mat,
    pos: usize,
    label: String,
}

impl<'a> MatSource<'a> {
    /// Borrowing source over `x`, labeled `"memory"`.
    pub fn new(x: &'a Mat) -> Self {
        Self { x, pos: 0, label: "memory".into() }
    }
}

impl DataSource for MatSource<'_> {
    fn rows(&self) -> usize {
        self.x.rows()
    }

    fn cols(&self) -> usize {
        self.x.cols()
    }

    fn reset(&mut self) -> Result<(), IcaError> {
        self.pos = 0;
        Ok(())
    }

    fn next_chunk(&mut self, max_cols: usize) -> Result<Option<Mat>, IcaError> {
        let chunk = mat_chunk(self.x, self.pos, max_cols);
        if let Some(c) = &chunk {
            self.pos += c.cols();
        }
        Ok(chunk)
    }

    fn skip_cols(&mut self, cols: usize) -> Result<usize, IcaError> {
        let skipped = cols.min(self.x.cols() - self.pos);
        self.pos += skipped;
        Ok(skipped)
    }

    fn label(&self) -> String {
        self.label.clone()
    }
}

/// On-disk matrix formats the CLI understands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// `{"rows": R, "cols": C, "data": [row-major f64]}` (fully loaded,
    /// then streamed from memory).
    Json,
    /// `FICA1` raw little-endian f64 binary (streamed).
    Bin,
    /// One sample per line, comma-separated signals (streamed).
    Csv,
}

impl Format {
    /// Short stable identifier used by the CLI (`--format`).
    pub fn id(self) -> &'static str {
        match self {
            Format::Json => "json",
            Format::Bin => "bin",
            Format::Csv => "csv",
        }
    }

    /// Parse a CLI identifier.
    pub fn from_id(s: &str) -> Option<Format> {
        Some(match s {
            "json" => Format::Json,
            "bin" => Format::Bin,
            "csv" => Format::Csv,
            _ => return None,
        })
    }

    /// Infer a format from a path's extension (case-insensitive).
    pub fn infer(path: impl AsRef<Path>) -> Option<Format> {
        let ext = path.as_ref().extension()?.to_str()?.to_ascii_lowercase();
        Format::from_id(&ext)
    }
}

/// Open a path as a [`DataSource`] in the given format.
///
/// JSON files are fully loaded (the format is not streamable) and served
/// through a [`MemSource`]; `bin` and `csv` stream from disk.
pub fn open_source(
    path: impl AsRef<Path>,
    format: Format,
) -> Result<Box<dyn DataSource>, IcaError> {
    let path = path.as_ref();
    Ok(match format {
        Format::Json => Box::new(MemSource::with_label(
            read_matrix_json(path)?,
            path.display().to_string(),
        )),
        Format::Bin => Box::new(BinSource::open(path)?),
        Format::Csv => Box::new(CsvSource::open(path)?),
    })
}

/// Drain a source into a dense `N×T` matrix, chunk by chunk, with the
/// pipeline's usual shape and completeness checks — the one assembly
/// loop behind `convert_to`'s JSON arm, `fica smoke`, and tests. The
/// source is reset first.
pub fn read_dense(src: &mut dyn DataSource, chunk_cols: usize) -> Result<Mat, IcaError> {
    let (n, t) = (src.rows(), src.cols());
    let chunk_cols = chunk_cols.max(1);
    src.reset()?;
    let mut full = Mat::zeros(n, t);
    let mut off = 0usize;
    while let Some(chunk) = src.next_chunk(chunk_cols)? {
        copy_columns(&mut full, off, &chunk, src)?;
        off += chunk.cols();
    }
    check_complete(off, t, src)?;
    Ok(full)
}

/// Stream a source into a file of the given format (`fica convert`).
///
/// `bin` and `csv` outputs are written chunk-by-chunk; `json` has no
/// streamable layout, so it is assembled in memory first.
pub fn convert_to(
    src: &mut dyn DataSource,
    path: impl AsRef<Path>,
    format: Format,
    chunk_cols: usize,
) -> Result<(), IcaError> {
    let path = path.as_ref();
    let (n, t) = (src.rows(), src.cols());
    let chunk_cols = chunk_cols.max(1);
    src.reset()?;
    match format {
        Format::Json => write_matrix_json(path, &read_dense(src, chunk_cols)?),
        Format::Bin => {
            let mut out = BinWriter::create(path, n, t)?;
            while let Some(chunk) = src.next_chunk(chunk_cols)? {
                out.write_chunk(&chunk)?;
            }
            out.finish()
        }
        Format::Csv => {
            let mut out = CsvWriter::create(path, n, t)?;
            while let Some(chunk) = src.next_chunk(chunk_cols)? {
                out.write_chunk(&chunk)?;
            }
            out.finish()
        }
    }
}

/// Shared bookkeeping for the streaming writers: a declared `rows × cols`
/// promise, admission checks per chunk (row agreement, overrun,
/// finiteness), and the fulfilled-at-finish check. Keeps the bin and csv
/// contracts identical by construction.
pub(crate) struct WritePromise {
    label: String,
    rows: usize,
    cols: usize,
    written: usize,
}

impl WritePromise {
    pub(crate) fn new(label: String, rows: usize, cols: usize) -> Result<WritePromise, IcaError> {
        if rows == 0 || cols == 0 {
            return Err(IcaError::invalid_input(format!(
                "{label}: refusing to write an empty {rows}x{cols} matrix"
            )));
        }
        Ok(WritePromise { label, rows, cols, written: 0 })
    }

    pub(crate) fn label(&self) -> &str {
        &self.label
    }

    /// Validate a chunk and count its samples against the promise.
    pub(crate) fn admit(&mut self, chunk: &Mat) -> Result<(), IcaError> {
        if chunk.rows() != self.rows {
            return Err(IcaError::DimensionMismatch {
                what: format!("chunk for {}", self.label),
                expected: (self.rows, chunk.cols()),
                got: (chunk.rows(), chunk.cols()),
            });
        }
        if self.written.checked_add(chunk.cols()).map_or(true, |total| total > self.cols) {
            return Err(IcaError::invalid_input(format!(
                "{}: chunk overruns the declared {} samples",
                self.label, self.cols
            )));
        }
        if !chunk.as_slice().iter().all(|v| v.is_finite()) {
            return Err(IcaError::NonFinite { what: format!("chunk for {}", self.label) });
        }
        self.written += chunk.cols();
        Ok(())
    }

    /// Every promised sample must have been written.
    pub(crate) fn fulfilled(&self) -> Result<(), IcaError> {
        if self.written != self.cols {
            return Err(IcaError::invalid_input(format!(
                "{}: wrote {} of {} promised samples",
                self.label, self.written, self.cols
            )));
        }
        Ok(())
    }
}

pub(crate) fn copy_columns(
    dst: &mut Mat,
    off: usize,
    chunk: &Mat,
    src: &dyn DataSource,
) -> Result<(), IcaError> {
    let end = match off.checked_add(chunk.cols()) {
        Some(end) if chunk.rows() == dst.rows() && end <= dst.cols() => end,
        _ => {
            return Err(IcaError::invalid_input(format!(
                "source {} yielded a mis-shaped chunk ({}x{} at column {off} of a {}x{} stream)",
                src.label(),
                chunk.rows(),
                chunk.cols(),
                dst.rows(),
                dst.cols()
            )));
        }
    };
    for i in 0..dst.rows() {
        dst.row_mut(i)[off..end].copy_from_slice(chunk.row(i));
    }
    Ok(())
}

/// Monotone suffix so scratch paths from one process never collide.
static SCRATCH_SEQ: AtomicU64 = AtomicU64::new(0);

/// A uniquely named temporary file that is **removed on drop** — the
/// RAII guard behind the out-of-core pipeline's whitened scratch. The
/// file is created exclusively at construction and the open handle is
/// kept (see [`ScratchFile::take_file`]), and `Drop` unlinks the path,
/// so the scratch disappears on success and on every error path alike.
#[derive(Debug)]
pub struct ScratchFile {
    path: PathBuf,
    /// The exclusively-created handle, held so the writer can use it
    /// directly instead of re-opening (and truncating) by path.
    file: Option<std::fs::File>,
}

impl ScratchFile {
    /// Create a fresh scratch file under `dir` (created if missing;
    /// default: the system temp dir). Names embed the process id and a
    /// process-wide sequence number: `fica-scratch-<tag>-<pid>-<seq>.bin`.
    ///
    /// The file is created **exclusively** (`O_EXCL`), so a leftover
    /// from a crashed run with a recycled pid — or a pre-planted
    /// symlink in a world-writable temp dir — is skipped instead of
    /// truncated, and the handle is retained so nothing ever re-opens
    /// the path for writing. On a persistent creation failure (e.g. an
    /// unwritable directory) the path is still reserved with no handle,
    /// and the writer surfaces the typed Io error.
    pub fn new_in(dir: Option<&Path>, tag: &str) -> ScratchFile {
        let dir = dir.map(Path::to_path_buf).unwrap_or_else(std::env::temp_dir);
        // Best-effort: if this fails, the writer will surface a typed Io.
        let _ = std::fs::create_dir_all(&dir);
        let pid = std::process::id();
        for _ in 0..1000 {
            let seq = SCRATCH_SEQ.fetch_add(1, Ordering::Relaxed);
            let candidate = dir.join(format!("fica-scratch-{tag}-{pid}-{seq}.bin"));
            match std::fs::OpenOptions::new()
                .write(true)
                .create_new(true)
                .open(&candidate)
            {
                Ok(file) => return ScratchFile { path: candidate, file: Some(file) },
                Err(e) if e.kind() == std::io::ErrorKind::AlreadyExists => continue,
                // Unwritable dir etc.: reserve the name anyway and let
                // the writer produce the typed error.
                Err(_) => return ScratchFile { path: candidate, file: None },
            }
        }
        let path = dir.join(format!("fica-scratch-{tag}-{pid}-exhausted.bin"));
        ScratchFile { path, file: None }
    }

    /// The reserved scratch path (exists until drop).
    pub fn path(&self) -> &Path {
        &self.path
    }

    /// Surrender the exclusively-created write handle (None if creation
    /// failed, or if it was already taken).
    pub fn take_file(&mut self) -> Option<std::fs::File> {
        self.file.take()
    }
}

impl Drop for ScratchFile {
    fn drop(&mut self) {
        // Close any still-held handle first so the unlink also succeeds
        // on platforms that refuse to remove open files.
        drop(self.file.take());
        let _ = std::fs::remove_file(&self.path);
    }
}

pub(crate) fn check_complete(
    got: usize,
    want: usize,
    src: &dyn DataSource,
) -> Result<(), IcaError> {
    if got != want {
        return Err(IcaError::invalid_input(format!(
            "source {} yielded {got} samples but promised {want}",
            src.label()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_source_chunks_cover_everything() {
        let x = Mat::from_fn(3, 10, |i, j| (i * 10 + j) as f64);
        let mut src = MemSource::new(x.clone());
        for chunk_cols in [1, 3, 4, 10, 99] {
            src.reset().unwrap();
            let mut seen = 0usize;
            while let Some(c) = src.next_chunk(chunk_cols).unwrap() {
                assert_eq!(c.rows(), 3);
                assert!(c.cols() >= 1 && c.cols() <= chunk_cols);
                for i in 0..3 {
                    for j in 0..c.cols() {
                        assert_eq!(c[(i, j)], x[(i, seen + j)]);
                    }
                }
                seen += c.cols();
            }
            assert_eq!(seen, 10, "chunk_cols {chunk_cols}");
        }
    }

    #[test]
    fn format_ids_roundtrip_and_infer() {
        for f in [Format::Json, Format::Bin, Format::Csv] {
            assert_eq!(Format::from_id(f.id()), Some(f));
        }
        assert_eq!(Format::from_id("hdf5"), None);
        assert_eq!(Format::infer("x.bin"), Some(Format::Bin));
        assert_eq!(Format::infer("x.CSV"), Some(Format::Csv));
        assert_eq!(Format::infer("dir/x.json"), Some(Format::Json));
        assert_eq!(Format::infer("noext"), None);
    }

    #[test]
    fn scratch_file_is_unique_and_removed_on_drop() {
        let dir = std::env::temp_dir().join("fica_scratch_unit_test");
        let a = ScratchFile::new_in(Some(&dir), "t");
        let b = ScratchFile::new_in(Some(&dir), "t");
        assert_ne!(a.path(), b.path(), "scratch paths must not collide");
        // Reservation creates the files exclusively, so a stale path is
        // never reused.
        assert!(a.path().exists() && b.path().exists());
        std::fs::write(a.path(), b"payload").unwrap();
        let kept = a.path().to_path_buf();
        drop(a);
        assert!(!kept.exists(), "scratch file must vanish on drop");
        let kept = b.path().to_path_buf();
        drop(b);
        assert!(!kept.exists(), "empty scratch must vanish on drop too");
    }

    /// A leftover file at the first candidate path (crashed run + pid
    /// reuse, or a pre-planted symlink) must be skipped, not truncated.
    #[test]
    fn scratch_file_skips_preexisting_paths() {
        let dir = std::env::temp_dir().join("fica_scratch_unit_test_skip");
        let probe = ScratchFile::new_in(Some(&dir), "s");
        // Plant a file at the *next* sequence number's path.
        let name = probe
            .path()
            .file_name()
            .unwrap()
            .to_string_lossy()
            .into_owned();
        let (prefix, seq_ext) = name.rsplit_once('-').unwrap();
        let seq: u64 = seq_ext.trim_end_matches(".bin").parse().unwrap();
        let planted = dir.join(format!("{prefix}-{}.bin", seq + 1));
        std::fs::write(&planted, b"stale").unwrap();
        let fresh = ScratchFile::new_in(Some(&dir), "s");
        assert_ne!(fresh.path(), planted.as_path(), "must skip the occupied path");
        assert_eq!(std::fs::read(&planted).unwrap(), b"stale", "planted file untouched");
        std::fs::remove_file(&planted).unwrap();
    }

    #[test]
    fn convert_between_all_formats_roundtrips() {
        let dir = std::env::temp_dir().join("fica_data_convert_test");
        std::fs::create_dir_all(&dir).unwrap();
        let x = Mat::from_fn(4, 23, |i, j| (i as f64 - 1.5) * 0.25 + (j as f64) * 0.01);
        for format in [Format::Json, Format::Bin, Format::Csv] {
            let path = dir.join(format!("m.{}", format.id()));
            let mut src = MemSource::new(x.clone());
            convert_to(&mut src, &path, format, 7).unwrap();
            let mut back = open_source(&path, format).unwrap();
            assert_eq!((back.rows(), back.cols()), (4, 23));
            let mut full = Mat::zeros(4, 23);
            let mut off = 0;
            while let Some(c) = back.next_chunk(5).unwrap() {
                copy_columns(&mut full, off, &c, back.as_ref()).unwrap();
                off += c.cols();
            }
            assert_eq!(off, 23);
            assert!(
                full.max_abs_diff(&x) == 0.0,
                "{}: lossy roundtrip",
                format.id()
            );
        }
    }
}

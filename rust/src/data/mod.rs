//! Streaming data plane: chunked ingestion of `X ∈ R^{N×T}`.
//!
//! Picard's cost is dominated by Θ(N²T) sweeps over T-long recordings, and
//! real recordings do not arrive as one in-memory JSON matrix. This module
//! is the layer between I/O and the solver:
//!
//! - [`DataSource`] — a resettable iterator over **column chunks** of `X`
//!   (signals in rows, samples in columns). Two passes are all the
//!   pipeline ever needs: one for moments, one for whitening.
//! - [`MemSource`] — adapter over an in-memory [`Mat`].
//! - [`BinSource`] / [`BinWriter`] — the `FICA1` raw little-endian f64
//!   binary format (24-byte validated header, sample-major frames).
//! - [`CsvSource`] / [`CsvWriter`] — one sample per line, one field per
//!   signal.
//! - [`StreamingStats`] — one-pass mean + covariance accumulator, so the
//!   whitener is computed without materializing the raw matrix.
//!
//! Every file implementation is fail-closed: bad magic, length lies,
//! ragged rows, unparsable or non-finite values are typed [`IcaError`]s,
//! never panics. In-memory adapters trust their caller (the estimator
//! validates finiteness once, in `preprocess_source`).

mod bin;
mod csv;
mod stats;

pub use bin::{write_bin, BinSource, BinWriter, BIN_MAGIC};
pub use csv::{write_csv, CsvSource, CsvWriter};
pub use stats::StreamingStats;

use crate::error::IcaError;
use crate::linalg::Mat;
use crate::util::{read_matrix_json, write_matrix_json};
use std::path::Path;

/// Default number of sample columns per chunk on the streaming paths.
///
/// Large enough that the per-chunk matmuls amortize dispatch, small enough
/// that a chunk of a wide recording stays cache- and memory-friendly
/// (N=64 ⇒ ~4 MB per chunk).
pub const DEFAULT_CHUNK_COLS: usize = 8192;

/// A resettable producer of column chunks of a fixed-shape matrix
/// `X ∈ R^{N×T}` (signals in rows, samples in columns).
///
/// The contract mirrors what the two-pass preprocessing pipeline needs:
/// dimensions are known up front, [`DataSource::reset`] rewinds to the
/// first sample, and [`DataSource::next_chunk`] yields `X[:, p..p+c]`
/// with `1 <= c <= max_cols` until the stream is exhausted.
pub trait DataSource {
    /// Number of signals N (rows of `X`).
    fn rows(&self) -> usize;

    /// Number of samples T (columns of `X`).
    fn cols(&self) -> usize;

    /// Rewind to the first sample.
    fn reset(&mut self) -> Result<(), IcaError>;

    /// The next column chunk (`N × c`, `1 <= c <= max_cols.max(1)`), or
    /// `None` once all T samples have been yielded since the last reset.
    fn next_chunk(&mut self, max_cols: usize) -> Result<Option<Mat>, IcaError>;

    /// Whether every yielded value is already guaranteed finite (file
    /// sources reject NaN/∞ while parsing). When `true` the pipeline
    /// skips its own O(N·T) finiteness scan.
    fn validates_finite(&self) -> bool {
        false
    }

    /// Human-readable description of the source for error messages.
    fn label(&self) -> String;
}

/// In-memory [`DataSource`] over a [`Mat`] (the trusted adapter: data
/// already in memory is validated by the pipeline, not re-parsed here).
pub struct MemSource {
    x: Mat,
    pos: usize,
    label: String,
}

impl MemSource {
    pub fn new(x: Mat) -> Self {
        Self::with_label(x, "memory")
    }

    pub fn with_label(x: Mat, label: impl Into<String>) -> Self {
        Self { x, pos: 0, label: label.into() }
    }

    /// Borrow the underlying matrix.
    pub fn data(&self) -> &Mat {
        &self.x
    }
}

impl DataSource for MemSource {
    fn rows(&self) -> usize {
        self.x.rows()
    }

    fn cols(&self) -> usize {
        self.x.cols()
    }

    fn reset(&mut self) -> Result<(), IcaError> {
        self.pos = 0;
        Ok(())
    }

    fn next_chunk(&mut self, max_cols: usize) -> Result<Option<Mat>, IcaError> {
        if self.pos >= self.x.cols() {
            return Ok(None);
        }
        let c = max_cols.max(1).min(self.x.cols() - self.pos);
        let pos = self.pos;
        let chunk = Mat::from_fn(self.x.rows(), c, |i, j| self.x[(i, pos + j)]);
        self.pos += c;
        Ok(Some(chunk))
    }

    fn label(&self) -> String {
        self.label.clone()
    }
}

/// On-disk matrix formats the CLI understands.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Format {
    /// `{"rows": R, "cols": C, "data": [row-major f64]}` (fully loaded,
    /// then streamed from memory).
    Json,
    /// `FICA1` raw little-endian f64 binary (streamed).
    Bin,
    /// One sample per line, comma-separated signals (streamed).
    Csv,
}

impl Format {
    /// Short stable identifier used by the CLI (`--format`).
    pub fn id(self) -> &'static str {
        match self {
            Format::Json => "json",
            Format::Bin => "bin",
            Format::Csv => "csv",
        }
    }

    /// Parse a CLI identifier.
    pub fn from_id(s: &str) -> Option<Format> {
        Some(match s {
            "json" => Format::Json,
            "bin" => Format::Bin,
            "csv" => Format::Csv,
            _ => return None,
        })
    }

    /// Infer a format from a path's extension (case-insensitive).
    pub fn infer(path: impl AsRef<Path>) -> Option<Format> {
        let ext = path.as_ref().extension()?.to_str()?.to_ascii_lowercase();
        Format::from_id(&ext)
    }
}

/// Open a path as a [`DataSource`] in the given format.
///
/// JSON files are fully loaded (the format is not streamable) and served
/// through a [`MemSource`]; `bin` and `csv` stream from disk.
pub fn open_source(
    path: impl AsRef<Path>,
    format: Format,
) -> Result<Box<dyn DataSource>, IcaError> {
    let path = path.as_ref();
    Ok(match format {
        Format::Json => Box::new(MemSource::with_label(
            read_matrix_json(path)?,
            path.display().to_string(),
        )),
        Format::Bin => Box::new(BinSource::open(path)?),
        Format::Csv => Box::new(CsvSource::open(path)?),
    })
}

/// Stream a source into a file of the given format (`fica convert`).
///
/// `bin` and `csv` outputs are written chunk-by-chunk; `json` has no
/// streamable layout, so it is assembled in memory first.
pub fn convert_to(
    src: &mut dyn DataSource,
    path: impl AsRef<Path>,
    format: Format,
    chunk_cols: usize,
) -> Result<(), IcaError> {
    let path = path.as_ref();
    let (n, t) = (src.rows(), src.cols());
    let chunk_cols = chunk_cols.max(1);
    src.reset()?;
    match format {
        Format::Json => {
            let mut full = Mat::zeros(n, t);
            let mut off = 0usize;
            while let Some(chunk) = src.next_chunk(chunk_cols)? {
                copy_columns(&mut full, off, &chunk, src)?;
                off += chunk.cols();
            }
            check_complete(off, t, src)?;
            write_matrix_json(path, &full)
        }
        Format::Bin => {
            let mut out = BinWriter::create(path, n, t)?;
            while let Some(chunk) = src.next_chunk(chunk_cols)? {
                out.write_chunk(&chunk)?;
            }
            out.finish()
        }
        Format::Csv => {
            let mut out = CsvWriter::create(path, n, t)?;
            while let Some(chunk) = src.next_chunk(chunk_cols)? {
                out.write_chunk(&chunk)?;
            }
            out.finish()
        }
    }
}

/// Shared bookkeeping for the streaming writers: a declared `rows × cols`
/// promise, admission checks per chunk (row agreement, overrun,
/// finiteness), and the fulfilled-at-finish check. Keeps the bin and csv
/// contracts identical by construction.
pub(crate) struct WritePromise {
    label: String,
    rows: usize,
    cols: usize,
    written: usize,
}

impl WritePromise {
    pub(crate) fn new(label: String, rows: usize, cols: usize) -> Result<WritePromise, IcaError> {
        if rows == 0 || cols == 0 {
            return Err(IcaError::invalid_input(format!(
                "{label}: refusing to write an empty {rows}x{cols} matrix"
            )));
        }
        Ok(WritePromise { label, rows, cols, written: 0 })
    }

    pub(crate) fn label(&self) -> &str {
        &self.label
    }

    /// Validate a chunk and count its samples against the promise.
    pub(crate) fn admit(&mut self, chunk: &Mat) -> Result<(), IcaError> {
        if chunk.rows() != self.rows {
            return Err(IcaError::DimensionMismatch {
                what: format!("chunk for {}", self.label),
                expected: (self.rows, chunk.cols()),
                got: (chunk.rows(), chunk.cols()),
            });
        }
        if self.written + chunk.cols() > self.cols {
            return Err(IcaError::invalid_input(format!(
                "{}: chunk overruns the declared {} samples",
                self.label, self.cols
            )));
        }
        if !chunk.as_slice().iter().all(|v| v.is_finite()) {
            return Err(IcaError::NonFinite { what: format!("chunk for {}", self.label) });
        }
        self.written += chunk.cols();
        Ok(())
    }

    /// Every promised sample must have been written.
    pub(crate) fn fulfilled(&self) -> Result<(), IcaError> {
        if self.written != self.cols {
            return Err(IcaError::invalid_input(format!(
                "{}: wrote {} of {} promised samples",
                self.label, self.written, self.cols
            )));
        }
        Ok(())
    }
}

pub(crate) fn copy_columns(
    dst: &mut Mat,
    off: usize,
    chunk: &Mat,
    src: &dyn DataSource,
) -> Result<(), IcaError> {
    if chunk.rows() != dst.rows() || off + chunk.cols() > dst.cols() {
        return Err(IcaError::invalid_input(format!(
            "source {} yielded a mis-shaped chunk ({}x{} at column {off} of a {}x{} stream)",
            src.label(),
            chunk.rows(),
            chunk.cols(),
            dst.rows(),
            dst.cols()
        )));
    }
    for i in 0..dst.rows() {
        dst.row_mut(i)[off..off + chunk.cols()].copy_from_slice(chunk.row(i));
    }
    Ok(())
}

pub(crate) fn check_complete(
    got: usize,
    want: usize,
    src: &dyn DataSource,
) -> Result<(), IcaError> {
    if got != want {
        return Err(IcaError::invalid_input(format!(
            "source {} yielded {got} samples but promised {want}",
            src.label()
        )));
    }
    Ok(())
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn mem_source_chunks_cover_everything() {
        let x = Mat::from_fn(3, 10, |i, j| (i * 10 + j) as f64);
        let mut src = MemSource::new(x.clone());
        for chunk_cols in [1, 3, 4, 10, 99] {
            src.reset().unwrap();
            let mut seen = 0usize;
            while let Some(c) = src.next_chunk(chunk_cols).unwrap() {
                assert_eq!(c.rows(), 3);
                assert!(c.cols() >= 1 && c.cols() <= chunk_cols);
                for i in 0..3 {
                    for j in 0..c.cols() {
                        assert_eq!(c[(i, j)], x[(i, seen + j)]);
                    }
                }
                seen += c.cols();
            }
            assert_eq!(seen, 10, "chunk_cols {chunk_cols}");
        }
    }

    #[test]
    fn format_ids_roundtrip_and_infer() {
        for f in [Format::Json, Format::Bin, Format::Csv] {
            assert_eq!(Format::from_id(f.id()), Some(f));
        }
        assert_eq!(Format::from_id("hdf5"), None);
        assert_eq!(Format::infer("x.bin"), Some(Format::Bin));
        assert_eq!(Format::infer("x.CSV"), Some(Format::Csv));
        assert_eq!(Format::infer("dir/x.json"), Some(Format::Json));
        assert_eq!(Format::infer("noext"), None);
    }

    #[test]
    fn convert_between_all_formats_roundtrips() {
        let dir = std::env::temp_dir().join("fica_data_convert_test");
        std::fs::create_dir_all(&dir).unwrap();
        let x = Mat::from_fn(4, 23, |i, j| (i as f64 - 1.5) * 0.25 + (j as f64) * 0.01);
        for format in [Format::Json, Format::Bin, Format::Csv] {
            let path = dir.join(format!("m.{}", format.id()));
            let mut src = MemSource::new(x.clone());
            convert_to(&mut src, &path, format, 7).unwrap();
            let mut back = open_source(&path, format).unwrap();
            assert_eq!((back.rows(), back.cols()), (4, 23));
            let mut full = Mat::zeros(4, 23);
            let mut off = 0;
            while let Some(c) = back.next_chunk(5).unwrap() {
                copy_columns(&mut full, off, &c, back.as_ref()).unwrap();
                off += c.cols();
            }
            assert_eq!(off, 23);
            assert!(
                full.max_abs_diff(&x) == 0.0,
                "{}: lossy roundtrip",
                format.id()
            );
        }
    }
}

//! The `FICA1` raw binary matrix format.
//!
//! Layout (all integers little-endian):
//!
//! ```text
//! offset  0: 8 bytes   magic b"FICA1\0\0\0"
//! offset  8: u64       rows (N, signals)
//! offset 16: u64       cols (T, samples)
//! offset 24: rows*cols little-endian f64, sample-major: sample t is the
//!            N consecutive values X[0][t], X[1][t], …, X[N-1][t]
//! ```
//!
//! Sample-major frames are the natural append order for a recording and
//! let [`BinSource`] stream column chunks with purely sequential reads.
//! Parsing is fail-closed: a bad magic, a zero dimension, a file length
//! that disagrees with the header, or a non-finite value is a typed
//! [`IcaError`], never a panic.

use crate::error::IcaError;
use crate::linalg::Mat;
use std::fs::File;
use std::io::{BufReader, BufWriter, Read, Seek, SeekFrom, Write};
use std::path::Path;

/// The 8-byte magic that opens every `FICA1` file.
pub const BIN_MAGIC: [u8; 8] = *b"FICA1\0\0\0";

const HEADER_LEN: u64 = 24;

/// Streaming reader for `FICA1` files.
pub struct BinSource {
    reader: BufReader<File>,
    path: String,
    n: usize,
    t: usize,
    pos: usize,
}

impl BinSource {
    /// Open and validate a `FICA1` file: magic, non-zero dimensions, and
    /// an exact match between the header's promise and the file length.
    pub fn open(path: impl AsRef<Path>) -> Result<BinSource, IcaError> {
        let path = path.as_ref();
        let label = path.display().to_string();
        let file = File::open(path).map_err(|e| IcaError::io(label.clone(), e))?;
        let file_len = file
            .metadata()
            .map_err(|e| IcaError::io(label.clone(), e))?
            .len();
        let mut reader = BufReader::new(file);
        let mut header = [0u8; HEADER_LEN as usize];
        reader.read_exact(&mut header).map_err(|_| {
            IcaError::invalid_input(format!("{label}: too short for a FICA1 header"))
        })?;
        if header[..8] != BIN_MAGIC {
            return Err(IcaError::invalid_input(format!(
                "{label}: bad magic (not a FICA1 file)"
            )));
        }
        let mut word = [0u8; 8];
        word.copy_from_slice(&header[8..16]);
        let rows = u64::from_le_bytes(word);
        word.copy_from_slice(&header[16..24]);
        let cols = u64::from_le_bytes(word);
        if rows == 0 || cols == 0 {
            return Err(IcaError::invalid_input(format!(
                "{label}: empty matrix ({rows}x{cols}) in header"
            )));
        }
        let n = usize::try_from(rows)
            .map_err(|_| IcaError::invalid_input(format!("{label}: rows {rows} overflows")))?;
        let t = usize::try_from(cols)
            .map_err(|_| IcaError::invalid_input(format!("{label}: cols {cols} overflows")))?;
        // Fail closed at open: the payload must be exactly rows*cols*8
        // bytes, with the size computation itself guarded by checked_mul
        // so an adversarial header cannot wrap it around.
        let expected = rows
            .checked_mul(cols)
            .and_then(|e| e.checked_mul(8))
            .and_then(|p| p.checked_add(HEADER_LEN))
            .ok_or_else(|| {
                IcaError::invalid_input(format!(
                    "{label}: header {rows}x{cols} overflows the representable file size"
                ))
            })?;
        if file_len != expected {
            return Err(IcaError::invalid_input(format!(
                "{label}: file length {file_len} != {expected} promised by header \
                 ({rows}x{cols} f64)"
            )));
        }
        Ok(BinSource { reader, path: label, n, t, pos: 0 })
    }
}

impl super::DataSource for BinSource {
    fn rows(&self) -> usize {
        self.n
    }

    fn cols(&self) -> usize {
        self.t
    }

    fn reset(&mut self) -> Result<(), IcaError> {
        self.reader
            .seek(SeekFrom::Start(HEADER_LEN))
            .map_err(|e| IcaError::io(self.path.clone(), e))?;
        self.pos = 0;
        Ok(())
    }

    fn next_chunk(&mut self, max_cols: usize) -> Result<Option<Mat>, IcaError> {
        if self.pos >= self.t {
            return Ok(None);
        }
        let c = max_cols.max(1).min(self.t - self.pos);
        let bytes = c
            .checked_mul(self.n)
            .and_then(|b| b.checked_mul(8))
            .ok_or_else(|| {
                IcaError::invalid_input(format!(
                    "{}: chunk of {c} samples x {} signals overflows",
                    self.path, self.n
                ))
            })?;
        let mut buf = vec![0u8; bytes];
        self.reader.read_exact(&mut buf).map_err(|_| {
            IcaError::invalid_input(format!(
                "{}: truncated at sample {} (file changed after open?)",
                self.path, self.pos
            ))
        })?;
        let mut chunk = Mat::zeros(self.n, c);
        // fica-lint: allow(unchecked-arith) — bounded: c·n·8 passed checked_mul above, so n·8 cannot overflow
        for (j, frame) in buf.chunks_exact(self.n * 8).enumerate() {
            for (i, bytes) in frame.chunks_exact(8).enumerate() {
                let mut word = [0u8; 8];
                word.copy_from_slice(bytes);
                let v = f64::from_le_bytes(word);
                if !v.is_finite() {
                    return Err(IcaError::NonFinite {
                        what: format!("{} (signal {i}, sample {})", self.path, self.pos + j),
                    });
                }
                chunk[(i, j)] = v;
            }
        }
        self.pos += c;
        Ok(Some(chunk))
    }

    /// Seek past whole samples instead of decoding them — O(1) where the
    /// default implementation would read and discard O(N·cols) bytes.
    fn skip_cols(&mut self, cols: usize) -> Result<usize, IcaError> {
        let skipped = cols.min(self.t - self.pos);
        if skipped == 0 {
            return Ok(0);
        }
        let bytes = skipped
            .checked_mul(self.n)
            .and_then(|b| b.checked_mul(8))
            .and_then(|b| i64::try_from(b).ok())
            .ok_or_else(|| {
                IcaError::invalid_input(format!(
                    "{}: skip of {skipped} samples x {} signals overflows",
                    self.path, self.n
                ))
            })?;
        self.reader
            .seek_relative(bytes)
            .map_err(|e| IcaError::io(self.path.clone(), e))?;
        self.pos += skipped;
        Ok(skipped)
    }

    fn validates_finite(&self) -> bool {
        true // next_chunk rejects NaN/∞ per value
    }

    fn label(&self) -> String {
        self.path.clone()
    }
}

/// Streaming writer for `FICA1` files: header up front, then sample
/// frames chunk by chunk. [`BinWriter::finish`] fails closed if fewer
/// samples were written than the header promised.
pub struct BinWriter {
    out: BufWriter<File>,
    promise: super::WritePromise,
}

impl BinWriter {
    /// Create `path` (truncating) and write the validated 24-byte
    /// header promising a `rows × cols` payload.
    pub fn create(path: impl AsRef<Path>, rows: usize, cols: usize) -> Result<BinWriter, IcaError> {
        let path = path.as_ref();
        let label = path.display().to_string();
        // Validate the shape promise before touching the filesystem.
        super::WritePromise::new(label.clone(), rows, cols)?;
        let file = File::create(path).map_err(|e| IcaError::io(label.clone(), e))?;
        Self::from_file(file, label, rows, cols)
    }

    /// Write into an already-open (empty) file handle — used by the
    /// out-of-core scratch path, whose [`super::ScratchFile`] created
    /// the file exclusively and must never re-open it by path.
    pub fn from_file(
        file: File,
        label: impl Into<String>,
        rows: usize,
        cols: usize,
    ) -> Result<BinWriter, IcaError> {
        let label = label.into();
        let promise = super::WritePromise::new(label.clone(), rows, cols)?;
        let mut out = BufWriter::new(file);
        let mut header = Vec::with_capacity(HEADER_LEN as usize);
        header.extend_from_slice(&BIN_MAGIC);
        header.extend_from_slice(&(rows as u64).to_le_bytes());
        header.extend_from_slice(&(cols as u64).to_le_bytes());
        out.write_all(&header).map_err(|e| IcaError::io(label, e))?;
        Ok(BinWriter { out, promise })
    }

    /// Append the samples of a column chunk.
    pub fn write_chunk(&mut self, chunk: &Mat) -> Result<(), IcaError> {
        self.promise.admit(chunk)?;
        for j in 0..chunk.cols() {
            for i in 0..chunk.rows() {
                self.out
                    .write_all(&chunk[(i, j)].to_le_bytes())
                    .map_err(|e| IcaError::io(self.promise.label().to_string(), e))?;
            }
        }
        Ok(())
    }

    /// Flush and close, verifying every promised sample was written.
    pub fn finish(mut self) -> Result<(), IcaError> {
        self.promise.fulfilled()?;
        self.out
            .flush()
            .map_err(|e| IcaError::io(self.promise.label().to_string(), e))
    }
}

/// Write a whole in-memory matrix as a `FICA1` file.
pub fn write_bin(path: impl AsRef<Path>, m: &Mat) -> Result<(), IcaError> {
    let mut w = BinWriter::create(path, m.rows(), m.cols())?;
    w.write_chunk(m)?;
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataSource;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fica_bin_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    fn drain(src: &mut dyn DataSource, chunk: usize) -> Mat {
        let mut out = Mat::zeros(src.rows(), src.cols());
        let mut off = 0;
        while let Some(c) = src.next_chunk(chunk).unwrap() {
            for i in 0..out.rows() {
                out.row_mut(i)[off..off + c.cols()].copy_from_slice(c.row(i));
            }
            off += c.cols();
        }
        assert_eq!(off, out.cols());
        out
    }

    #[test]
    fn roundtrip_is_bit_exact_and_resettable() {
        let p = tmp("rt.bin");
        let m = Mat::from_fn(3, 17, |i, j| (i as f64 + 0.5).powi(2) / (j as f64 + 1.0));
        write_bin(&p, &m).unwrap();
        let mut src = BinSource::open(&p).unwrap();
        assert_eq!((src.rows(), src.cols()), (3, 17));
        assert!(drain(&mut src, 5).max_abs_diff(&m) == 0.0);
        // Second pass after reset sees the same bytes.
        src.reset().unwrap();
        assert!(drain(&mut src, 17).max_abs_diff(&m) == 0.0);
        // Exhausted stream yields None until reset.
        assert!(src.next_chunk(4).unwrap().is_none());
    }

    #[test]
    fn skip_cols_seeks_without_decoding() {
        let p = tmp("skip.bin");
        let m = Mat::from_fn(2, 30, |i, j| (i * 100 + j) as f64);
        write_bin(&p, &m).unwrap();
        let mut src = BinSource::open(&p).unwrap();
        assert_eq!(src.skip_cols(10).unwrap(), 10);
        let c = src.next_chunk(5).unwrap().unwrap();
        assert_eq!(c[(0, 0)], 10.0);
        assert_eq!(c[(1, 0)], 110.0);
        // Skipping past the end is clamped, then the stream is done.
        assert_eq!(src.skip_cols(100).unwrap(), 15);
        assert!(src.next_chunk(4).unwrap().is_none());
        assert_eq!(src.skip_cols(3).unwrap(), 0);
        // Reset rewinds skips too.
        src.reset().unwrap();
        let c = src.next_chunk(1).unwrap().unwrap();
        assert_eq!(c[(0, 0)], 0.0);
    }

    #[test]
    fn open_fails_closed() {
        // Bad magic.
        let p = tmp("magic.bin");
        std::fs::write(&p, b"NOTFICA1aaaaaaaaaaaaaaaa").unwrap();
        assert!(matches!(
            BinSource::open(&p),
            Err(IcaError::InvalidInput { .. })
        ));
        // Too short for a header.
        let p = tmp("short.bin");
        std::fs::write(&p, b"FICA1").unwrap();
        assert!(BinSource::open(&p).is_err());
        // Length disagrees with header.
        let p = tmp("len.bin");
        write_bin(&p, &Mat::from_fn(2, 4, |i, j| (i + j) as f64)).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.truncate(bytes.len() - 8);
        std::fs::write(&p, &bytes).unwrap();
        assert!(matches!(
            BinSource::open(&p),
            Err(IcaError::InvalidInput { .. })
        ));
        // Truncated payload fails at open, not mid-stream.
        let p = tmp("trunc.bin");
        write_bin(&p, &Mat::from_fn(3, 9, |i, j| (i * j) as f64)).unwrap();
        let mut bytes = std::fs::read(&p).unwrap();
        bytes.truncate(24 + 3 * 4 * 8); // only 4 of 9 promised samples
        std::fs::write(&p, &bytes).unwrap();
        assert!(matches!(
            BinSource::open(&p),
            Err(IcaError::InvalidInput { .. })
        ));
        // A header whose rows*cols*8 wraps u64 must yield a typed error,
        // not a wrapped-around length check that happens to pass.
        let p = tmp("overflow.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&BIN_MAGIC);
        bytes.extend_from_slice(&(u64::MAX / 4).to_le_bytes());
        bytes.extend_from_slice(&9u64.to_le_bytes());
        bytes.extend_from_slice(&[0u8; 8]); // some payload so len > header
        std::fs::write(&p, &bytes).unwrap();
        match BinSource::open(&p) {
            Err(IcaError::InvalidInput { what }) => {
                assert!(what.contains("overflows"), "{what}");
            }
            other => panic!("expected overflow InvalidInput, got {other:?}"),
        }
        // Zero dimension.
        let p = tmp("zero.bin");
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&BIN_MAGIC);
        bytes.extend_from_slice(&0u64.to_le_bytes());
        bytes.extend_from_slice(&5u64.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        assert!(BinSource::open(&p).is_err());
        // Missing file is an Io error.
        assert!(matches!(
            BinSource::open(tmp("missing.bin")),
            Err(IcaError::Io { .. })
        ));
    }

    #[test]
    fn non_finite_values_rejected_on_read_and_write() {
        let mut m = Mat::from_fn(2, 3, |i, j| (i + j) as f64);
        m[(1, 2)] = f64::NAN;
        let p = tmp("nan.bin");
        assert!(matches!(write_bin(&p, &m), Err(IcaError::NonFinite { .. })));
        // Craft a file with an inf payload by hand.
        let mut bytes = Vec::new();
        bytes.extend_from_slice(&BIN_MAGIC);
        bytes.extend_from_slice(&1u64.to_le_bytes());
        bytes.extend_from_slice(&2u64.to_le_bytes());
        bytes.extend_from_slice(&1.0f64.to_le_bytes());
        bytes.extend_from_slice(&f64::INFINITY.to_le_bytes());
        std::fs::write(&p, &bytes).unwrap();
        let mut src = BinSource::open(&p).unwrap();
        assert!(matches!(
            src.next_chunk(8),
            Err(IcaError::NonFinite { .. })
        ));
    }

    #[test]
    fn writer_enforces_its_promise() {
        let p = tmp("promise.bin");
        let mut w = BinWriter::create(&p, 2, 10).unwrap();
        w.write_chunk(&Mat::zeros(2, 4)).unwrap();
        // Wrong row count.
        assert!(matches!(
            w.write_chunk(&Mat::zeros(3, 2)),
            Err(IcaError::DimensionMismatch { .. })
        ));
        // Overrun.
        assert!(w.write_chunk(&Mat::zeros(2, 7)).is_err());
        // Underrun at finish.
        assert!(matches!(
            w.finish(),
            Err(IcaError::InvalidInput { .. })
        ));
    }
}

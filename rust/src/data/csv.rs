//! CSV matrix I/O: one sample (column of `X`) per line, one field per
//! signal.
//!
//! The layout matches how multichannel recordings are exported in
//! practice: time flows down the file, channels across a line. There is
//! no header row; every line must have the same number of fields, every
//! field must parse as a finite f64 (surrounding spaces are tolerated).
//!
//! [`CsvSource::open`] makes one cheap validation pass (line count +
//! field-count agreement, no float parsing), then streams; values are
//! parsed lazily per chunk, so memory stays `O(N × chunk)`.

use crate::error::IcaError;
use crate::linalg::Mat;
use std::fs::File;
use std::io::{BufRead, BufReader, BufWriter, Seek, SeekFrom, Write};
use std::path::Path;

/// Streaming reader for sample-per-line CSV matrices.
pub struct CsvSource {
    reader: BufReader<File>,
    path: String,
    n: usize,
    t: usize,
    pos: usize,
    line: String,
}

impl CsvSource {
    /// Open and structurally validate a CSV file: at least one sample,
    /// a consistent field count on every line, at most one trailing
    /// newline. Field values are parsed during streaming.
    pub fn open(path: impl AsRef<Path>) -> Result<CsvSource, IcaError> {
        let path = path.as_ref();
        let label = path.display().to_string();
        let file = File::open(path).map_err(|e| IcaError::io(label.clone(), e))?;
        let mut reader = BufReader::new(file);
        let mut line = String::new();
        let (mut n, mut t) = (0usize, 0usize);
        loop {
            line.clear();
            let read = reader
                .read_line(&mut line)
                .map_err(|e| IcaError::io(label.clone(), e))?;
            if read == 0 {
                break;
            }
            let s = line.trim_end_matches(['\n', '\r']);
            if s.is_empty() {
                // Permissible only as a trailing newline.
                let mut rest = String::new();
                if reader
                    .read_line(&mut rest)
                    .map_err(|e| IcaError::io(label.clone(), e))?
                    == 0
                {
                    break;
                }
                return Err(IcaError::invalid_input(format!(
                    "{label}: blank line {} inside the data",
                    t + 1
                )));
            }
            let fields = s.split(',').count();
            if t == 0 {
                n = fields;
            } else if fields != n {
                return Err(IcaError::invalid_input(format!(
                    "{label}: line {} has {fields} fields, expected {n}",
                    t + 1
                )));
            }
            t += 1;
        }
        if t == 0 {
            return Err(IcaError::invalid_input(format!("{label}: empty CSV file")));
        }
        let mut src = CsvSource { reader, path: label, n, t, pos: 0, line };
        crate::data::DataSource::reset(&mut src)?;
        Ok(src)
    }
}

impl super::DataSource for CsvSource {
    fn rows(&self) -> usize {
        self.n
    }

    fn cols(&self) -> usize {
        self.t
    }

    fn reset(&mut self) -> Result<(), IcaError> {
        self.reader
            .seek(SeekFrom::Start(0))
            .map_err(|e| IcaError::io(self.path.clone(), e))?;
        self.pos = 0;
        Ok(())
    }

    fn next_chunk(&mut self, max_cols: usize) -> Result<Option<Mat>, IcaError> {
        if self.pos >= self.t {
            return Ok(None);
        }
        let c = max_cols.max(1).min(self.t - self.pos);
        let mut chunk = Mat::zeros(self.n, c);
        for j in 0..c {
            self.line.clear();
            let sample = self.pos + j;
            let read = self
                .reader
                .read_line(&mut self.line)
                .map_err(|e| IcaError::io(self.path.clone(), e))?;
            if read == 0 {
                return Err(IcaError::invalid_input(format!(
                    "{}: truncated at line {} (file changed after open?)",
                    self.path,
                    sample + 1
                )));
            }
            let s = self.line.trim_end_matches(['\n', '\r']);
            let mut fields = 0usize;
            for (i, tok) in s.split(',').enumerate() {
                fields += 1;
                if i >= self.n {
                    break;
                }
                let v: f64 = tok.trim().parse().map_err(|_| {
                    IcaError::invalid_input(format!(
                        "{}: line {}: {tok:?} is not a number",
                        self.path,
                        sample + 1
                    ))
                })?;
                if !v.is_finite() {
                    return Err(IcaError::NonFinite {
                        what: format!("{} (signal {i}, sample {sample})", self.path),
                    });
                }
                chunk[(i, j)] = v;
            }
            if fields != self.n {
                return Err(IcaError::invalid_input(format!(
                    "{}: line {} has {fields} fields, expected {} \
                     (file changed after open?)",
                    self.path,
                    sample + 1,
                    self.n
                )));
            }
        }
        self.pos += c;
        Ok(Some(chunk))
    }

    fn validates_finite(&self) -> bool {
        true // next_chunk rejects NaN/∞ per value
    }

    fn label(&self) -> String {
        self.path.clone()
    }
}

/// Streaming writer: one sample per line, shortest-roundtrip f64
/// formatting (the text survives a parse bit-exactly).
pub struct CsvWriter {
    out: BufWriter<File>,
    promise: super::WritePromise,
}

impl CsvWriter {
    /// Create `path` (truncating), promising a `rows × cols` payload
    /// checked at [`CsvWriter::finish`].
    pub fn create(path: impl AsRef<Path>, rows: usize, cols: usize) -> Result<CsvWriter, IcaError> {
        let path = path.as_ref();
        let label = path.display().to_string();
        let promise = super::WritePromise::new(label.clone(), rows, cols)?;
        let file = File::create(path).map_err(|e| IcaError::io(label, e))?;
        Ok(CsvWriter { out: BufWriter::new(file), promise })
    }

    /// Append the samples of a column chunk.
    pub fn write_chunk(&mut self, chunk: &Mat) -> Result<(), IcaError> {
        self.promise.admit(chunk)?;
        let mut line = String::new();
        for j in 0..chunk.cols() {
            line.clear();
            for i in 0..chunk.rows() {
                if i > 0 {
                    line.push(',');
                }
                line.push_str(&format!("{}", chunk[(i, j)]));
            }
            line.push('\n');
            self.out
                .write_all(line.as_bytes())
                .map_err(|e| IcaError::io(self.promise.label().to_string(), e))?;
        }
        Ok(())
    }

    /// Flush and close, verifying every promised sample was written.
    pub fn finish(mut self) -> Result<(), IcaError> {
        self.promise.fulfilled()?;
        self.out
            .flush()
            .map_err(|e| IcaError::io(self.promise.label().to_string(), e))
    }
}

/// Write a whole in-memory matrix as sample-per-line CSV.
pub fn write_csv(path: impl AsRef<Path>, m: &Mat) -> Result<(), IcaError> {
    let mut w = CsvWriter::create(path, m.rows(), m.cols())?;
    w.write_chunk(m)?;
    w.finish()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::DataSource;

    fn tmp(name: &str) -> std::path::PathBuf {
        let dir = std::env::temp_dir().join("fica_csv_test");
        std::fs::create_dir_all(&dir).unwrap();
        dir.join(name)
    }

    #[test]
    fn roundtrip_is_bit_exact() {
        let p = tmp("rt.csv");
        let m = Mat::from_fn(3, 11, |i, j| ((i * 31 + j) as f64 / 7.0 - 1.5).powi(3));
        write_csv(&p, &m).unwrap();
        let mut src = CsvSource::open(&p).unwrap();
        assert_eq!((src.rows(), src.cols()), (3, 11));
        let mut full = Mat::zeros(3, 11);
        let mut off = 0;
        while let Some(c) = src.next_chunk(4).unwrap() {
            for i in 0..3 {
                full.row_mut(i)[off..off + c.cols()].copy_from_slice(c.row(i));
            }
            off += c.cols();
        }
        assert_eq!(off, 11);
        assert!(full.max_abs_diff(&m) == 0.0, "csv roundtrip not exact");
        // Reset replays from the first sample.
        src.reset().unwrap();
        let c = src.next_chunk(2).unwrap().unwrap();
        assert_eq!(c[(0, 0)], m[(0, 0)]);
    }

    #[test]
    fn open_fails_closed() {
        // Ragged rows.
        let p = tmp("ragged.csv");
        std::fs::write(&p, "1,2,3\n4,5\n").unwrap();
        assert!(matches!(
            CsvSource::open(&p),
            Err(IcaError::InvalidInput { .. })
        ));
        // Interior blank line.
        let p = tmp("blank.csv");
        std::fs::write(&p, "1,2\n\n3,4\n").unwrap();
        assert!(CsvSource::open(&p).is_err());
        // Empty file.
        let p = tmp("empty.csv");
        std::fs::write(&p, "").unwrap();
        assert!(CsvSource::open(&p).is_err());
        // A single trailing newline is fine.
        let p = tmp("trailing.csv");
        std::fs::write(&p, "1,2\n3,4\n").unwrap();
        let src = CsvSource::open(&p).unwrap();
        assert_eq!((src.rows(), src.cols()), (2, 2));
    }

    #[test]
    fn bad_values_rejected_while_streaming() {
        let p = tmp("badval.csv");
        std::fs::write(&p, "1,2\nx,4\n").unwrap();
        let mut src = CsvSource::open(&p).unwrap();
        assert!(matches!(
            src.next_chunk(8),
            Err(IcaError::InvalidInput { .. })
        ));
        let p = tmp("nan.csv");
        std::fs::write(&p, "1,2\nNaN,4\n").unwrap();
        let mut src = CsvSource::open(&p).unwrap();
        assert!(matches!(src.next_chunk(8), Err(IcaError::NonFinite { .. })));
    }

    #[test]
    fn spaces_around_fields_tolerated() {
        let p = tmp("spaces.csv");
        std::fs::write(&p, " 1.5 , -2\n3,  4e-2\n").unwrap();
        let mut src = CsvSource::open(&p).unwrap();
        let c = src.next_chunk(8).unwrap().unwrap();
        assert_eq!(c[(0, 0)], 1.5);
        assert_eq!(c[(1, 0)], -2.0);
        assert_eq!(c[(1, 1)], 0.04);
    }
}

//! faster-ica: the Picard family of preconditioned ICA solvers from
//! "Faster ICA by preconditioning with Hessian approximations"
//! (Ablin, Cardoso & Gramfort, 2017), packaged as a production estimator.
//!
//! # Front door: the `Picard` estimator
//!
//! [`estimator::Picard`] is the supported entry point: a builder that
//! runs centering, whitening and the chosen solver end-to-end and hands
//! back a fitted, serializable [`estimator::IcaModel`]:
//!
//! ```
//! use faster_ica::estimator::Picard;
//! use faster_ica::signal;
//!
//! // A small synthetic mixture: 4 Laplace sources, 1500 samples.
//! let data = signal::experiment_a(4, 1500, 7);
//!
//! let model = Picard::new()
//!     .tol(1e-8)
//!     .max_iters(100)
//!     .fit(&data.x)
//!     .expect("fit");
//! assert!(model.fit_info().converged);
//!
//! // Sources for any batch drawn from the same mixture:
//! let sources = model.transform(&data.x).expect("transform");
//! assert_eq!(sources.rows(), 4);
//!
//! // The fitted artifact round-trips through JSON (fail-closed parsing).
//! let json = model.to_json_string().expect("serialize");
//! let back = faster_ica::estimator::IcaModel::from_json_str(&json).expect("load");
//! assert!(back.unmixing_matrix().max_abs_diff(&model.unmixing_matrix()) == 0.0);
//! ```
//!
//! Every user-reachable failure (rank-deficient data, shape mismatches,
//! non-finite inputs, malformed model files) is a typed
//! [`error::IcaError`], never a panic.
//!
//! # Layers
//!
//! - **Estimator** ([`estimator`]): `Picard` builder → [`preprocessing`]
//!   (centering + whitening) → [`ica`] solvers → `IcaModel` artifact.
//!   Fitted models serialize their sufficient statistics, so growing
//!   recordings refit incrementally: [`estimator::Picard::warm_start`]
//!   seeds the solver from a previous fit and
//!   [`estimator::Picard::fit_append`] merges the stored moments with
//!   one streaming pass over only the appended samples.
//! - **Algorithms** ([`ica`]): the paper's optimization suite —
//!   relative-gradient descent, Infomax SGD, the elementary quasi-Newton
//!   method (Alg. 2) and (preconditioned) L-BFGS (Alg. 3) over the
//!   block-diagonal Hessian approximations H̃¹/H̃² — on a pure-Rust
//!   [`linalg`] substrate.
//! - **Data plane** ([`data`]): chunked ingestion of large recordings —
//!   a [`data::DataSource`] trait over in-memory, `FICA1` binary, and CSV
//!   inputs, plus one-pass streaming whitening statistics feeding
//!   [`estimator::Picard::fit_source`], and RAII scratch files for the
//!   out-of-core path.
//! - **Backends** ([`backend`], [`runtime`]): the Θ(N²T) per-iteration
//!   statistics run on the always-available native backend, sharded
//!   across a worker-thread pool ([`backend::ShardedBackend`]),
//!   re-streamed from a whitened scratch file for out-of-core fits
//!   ([`backend::ChunkedBackend`], [`estimator::Picard::out_of_core`])
//!   or, behind the `pjrt` cargo feature, on AOT-compiled JAX/Pallas
//!   artifacts through a PJRT CPU client (Python is never on the
//!   request path).
//! - **Reproduction** ([`experiments`], [`coordinator`]): the paper's
//!   figure pipeline, driven by the `fica experiment` subcommand.
//! - **Serving** ([`daemon`]): `fica serve` keeps a resident process
//!   with a warm worker pool and an LRU model cache, speaking the
//!   length-prefixed `fica.wire/v1` protocol over TCP or Unix sockets;
//!   fit/refit/transform jobs run through a bounded queue with per-job
//!   cancellation and graceful drain on shutdown.
//! - **Registry** ([`registry`]): versioned, integrity-checked model
//!   artifacts — a fail-closed `fica.registry_manifest/v1` manifest,
//!   content-addressed artifact storage (SHA-256 of the exact bytes),
//!   auditable `fit_append` refit lineage, and the verifying
//!   [`registry::Resolver`] the daemon and CLI load deployed models
//!   through.
//!
//! The layer map, the numerical-equivalence contracts between execution
//! paths, and the out-of-core data flow are documented in
//! `ARCHITECTURE.md` at the repository root.
#![warn(missing_docs)]

pub mod backend;
pub mod cli;
pub mod coordinator;
pub mod daemon;
pub mod data;
pub mod error;
pub mod estimator;
pub mod experiments;
pub mod preprocessing;
pub mod signal;
pub mod bench;
pub mod ica;
pub mod linalg;
pub mod obs;
pub mod registry;
pub mod rng;
pub mod testkit;
pub mod runtime;
pub mod util;

pub use backend::SweepKernel;
pub use error::IcaError;
pub use estimator::{BackendChoice, IcaModel, Picard};

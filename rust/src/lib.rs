//! faster-ica: three-layer reproduction of "Faster ICA by preconditioning
//! with Hessian approximations" (Ablin, Cardoso & Gramfort, 2017).
//!
//! - **Layer 3 (this crate)**: the paper's optimization algorithms —
//!   relative-gradient ICA, block-diagonal Hessian approximations,
//!   preconditioned L-BFGS — plus the experiment coordinator and CLI.
//! - **Layer 2/1 (python/compile)**: JAX model + fused Pallas kernel,
//!   AOT-lowered once to HLO-text artifacts.
//! - **Runtime**: PJRT CPU client executing the artifacts from the Rust
//!   hot path (Python is never on the request path).
pub mod backend;
pub mod cli;
pub mod coordinator;
pub mod experiments;
pub mod preprocessing;
pub mod signal;
pub mod bench;
pub mod ica;
pub mod linalg;
pub mod rng;
pub mod testkit;
pub mod runtime;
pub mod util;

//! Sampling distributions used by the paper's experiments.
//!
//! - `Normal` — Box–Muller (polar form), Gaussian sources & mixing matrices.
//! - `Laplace` — inverse CDF; experiment A and the super-Gaussian third of
//!   experiment B (`p(x) ∝ exp(-|x|)`).
//! - `GeneralizedGaussian { beta }` — `p(x) ∝ exp(-|x/α|^β)`; experiment B's
//!   sub-Gaussian sources use β=3 (`p ∝ exp(-|x|³)`). Sampled exactly via a
//!   Gamma(1/β) transform (Nardon & Pianca 2009).
//! - `GaussianMixture` — experiment C's `α N(0,1) + (1-α) N(0,σ²)`.

use super::Pcg64;

/// A distribution from which f64 samples can be drawn.
pub trait Sample {
    /// Draw one sample.
    fn sample(&self, rng: &mut Pcg64) -> f64;

    /// Fill a slice with i.i.d. samples.
    fn fill(&self, rng: &mut Pcg64, out: &mut [f64]) {
        for x in out.iter_mut() {
            *x = self.sample(rng);
        }
    }

    /// Draw n i.i.d. samples.
    fn sample_n(&self, rng: &mut Pcg64, n: usize) -> Vec<f64> {
        let mut v = vec![0.0; n];
        self.fill(rng, &mut v);
        v
    }
}

/// Uniform on [lo, hi).
#[derive(Clone, Copy, Debug)]
pub struct Uniform {
    /// Inclusive lower bound.
    pub lo: f64,
    /// Exclusive upper bound.
    pub hi: f64,
}

impl Sample for Uniform {
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        self.lo + (self.hi - self.lo) * rng.next_f64()
    }
}

/// Gaussian N(mean, std²) via polar Box–Muller.
///
/// Stateless by design (we throw the second variate away) so that calls
/// compose deterministically regardless of interleaving across sources.
#[derive(Clone, Copy, Debug)]
pub struct Normal {
    /// Mean of the distribution.
    pub mean: f64,
    /// Standard deviation.
    pub std: f64,
}

impl Normal {
    /// N(0, 1).
    pub fn standard() -> Self {
        Self { mean: 0.0, std: 1.0 }
    }
}

impl Sample for Normal {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        loop {
            let u = 2.0 * rng.next_f64() - 1.0;
            let v = 2.0 * rng.next_f64() - 1.0;
            let s = u * u + v * v;
            if s > 0.0 && s < 1.0 {
                let f = (-2.0 * s.ln() / s).sqrt();
                return self.mean + self.std * u * f;
            }
        }
    }
}

/// Laplace(0, b): density `p(x) = exp(-|x|/b) / (2b)`; variance `2b²`.
/// The paper's experiment A uses b=1.
#[derive(Clone, Copy, Debug)]
pub struct Laplace {
    /// Scale parameter b.
    pub scale: f64,
}

impl Laplace {
    /// Laplace(0, 1).
    pub fn standard() -> Self {
        Self { scale: 1.0 }
    }
}

impl Sample for Laplace {
    #[inline]
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        // Inverse CDF: u ~ U(-1/2, 1/2), x = -b sgn(u) ln(1 - 2|u|).
        let u = rng.next_f64_open() - 0.5;
        -self.scale * u.signum() * (1.0 - 2.0 * u.abs()).ln()
    }
}

/// Generalized Gaussian `p(x) ∝ exp(-|x/α|^β)` with scale α and shape β.
///
/// β=2 recovers the Gaussian, β=1 the Laplace; β>2 is sub-Gaussian
/// (negative excess kurtosis). Sampling: if `G ~ Gamma(1/β, 1)` then
/// `x = α · s · G^{1/β}` with random sign s has the GG(α, β) law.
#[derive(Clone, Copy, Debug)]
pub struct GeneralizedGaussian {
    /// Scale parameter α.
    pub alpha: f64,
    /// Shape parameter β.
    pub beta: f64,
}

impl GeneralizedGaussian {
    /// Experiment B's sub-Gaussian source: `p(x) ∝ exp(-|x|³)`.
    pub fn cubic() -> Self {
        Self { alpha: 1.0, beta: 3.0 }
    }

    /// Variance of the distribution: α² Γ(3/β) / Γ(1/β).
    pub fn variance(&self) -> f64 {
        self.alpha * self.alpha * gamma_fn(3.0 / self.beta) / gamma_fn(1.0 / self.beta)
    }
}

impl Sample for GeneralizedGaussian {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        let g = sample_gamma(rng, 1.0 / self.beta);
        let sign = if rng.next_u64() & 1 == 0 { 1.0 } else { -1.0 };
        self.alpha * sign * g.powf(1.0 / self.beta)
    }
}

/// Two-component zero-mean Gaussian scale mixture
/// `α N(0,1) + (1-α) N(0, σ²)` — experiment C's source family.
#[derive(Clone, Copy, Debug)]
pub struct GaussianMixture {
    /// Weight of the unit-variance component.
    pub alpha: f64,
    /// Std-dev of the second component.
    pub sigma: f64,
}

impl Sample for GaussianMixture {
    fn sample(&self, rng: &mut Pcg64) -> f64 {
        let std = if rng.next_f64() < self.alpha { 1.0 } else { self.sigma };
        Normal { mean: 0.0, std }.sample(rng)
    }
}

/// Marsaglia–Tsang Gamma(shape, 1) sampler; for shape < 1 uses the
/// boost `Gamma(a) = Gamma(a+1) · U^{1/a}`.
fn sample_gamma(rng: &mut Pcg64, shape: f64) -> f64 {
    debug_assert!(shape > 0.0);
    if shape < 1.0 {
        let g = sample_gamma(rng, shape + 1.0);
        let u = rng.next_f64_open();
        return g * u.powf(1.0 / shape);
    }
    let d = shape - 1.0 / 3.0;
    let c = 1.0 / (9.0 * d).sqrt();
    loop {
        let x = Normal::standard().sample(rng);
        let v = (1.0 + c * x).powi(3);
        if v <= 0.0 {
            continue;
        }
        let u = rng.next_f64_open();
        if u.ln() < 0.5 * x * x + d - d * v + d * v.ln() {
            return d * v;
        }
    }
}

/// Lanczos approximation of Γ(x) for x > 0 (g=7, n=9 coefficients).
pub fn gamma_fn(x: f64) -> f64 {
    const G: f64 = 7.0;
    const C: [f64; 9] = [
        0.999_999_999_999_809_93,
        676.520_368_121_885_1,
        -1_259.139_216_722_402_8,
        771.323_428_777_653_13,
        -176.615_029_162_140_6,
        12.507_343_278_686_905,
        -0.138_571_095_265_720_12,
        9.984_369_578_019_571_6e-6,
        1.505_632_735_149_311_6e-7,
    ];
    if x < 0.5 {
        // Reflection formula.
        std::f64::consts::PI / ((std::f64::consts::PI * x).sin() * gamma_fn(1.0 - x))
    } else {
        let x = x - 1.0;
        let mut a = C[0];
        let t = x + G + 0.5;
        for (i, &c) in C.iter().enumerate().skip(1) {
            a += c / (x + i as f64);
        }
        (2.0 * std::f64::consts::PI).sqrt() * t.powf(x + 0.5) * (-t).exp() * a
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn moments(xs: &[f64]) -> (f64, f64, f64) {
        let n = xs.len() as f64;
        let mean = xs.iter().sum::<f64>() / n;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n;
        let kurt =
            xs.iter().map(|x| (x - mean).powi(4)).sum::<f64>() / n / (var * var) - 3.0;
        (mean, var, kurt)
    }

    #[test]
    fn gamma_fn_known_values() {
        assert!((gamma_fn(1.0) - 1.0).abs() < 1e-12);
        assert!((gamma_fn(2.0) - 1.0).abs() < 1e-12);
        assert!((gamma_fn(5.0) - 24.0).abs() < 1e-9);
        assert!((gamma_fn(0.5) - std::f64::consts::PI.sqrt()).abs() < 1e-10);
    }

    #[test]
    fn normal_moments() {
        let mut rng = Pcg64::new(1);
        let xs = Normal { mean: 2.0, std: 3.0 }.sample_n(&mut rng, 300_000);
        let (m, v, k) = moments(&xs);
        assert!((m - 2.0).abs() < 0.03, "mean={m}");
        assert!((v - 9.0).abs() < 0.15, "var={v}");
        assert!(k.abs() < 0.1, "kurtosis={k}");
    }

    #[test]
    fn laplace_moments() {
        let mut rng = Pcg64::new(2);
        let xs = Laplace::standard().sample_n(&mut rng, 300_000);
        let (m, v, k) = moments(&xs);
        assert!(m.abs() < 0.02, "mean={m}");
        assert!((v - 2.0).abs() < 0.05, "var={v}"); // Var = 2b²
        assert!((k - 3.0).abs() < 0.25, "kurtosis={k}"); // excess kurtosis 3
    }

    #[test]
    fn generalized_gaussian_cubic_is_sub_gaussian() {
        let mut rng = Pcg64::new(3);
        let gg = GeneralizedGaussian::cubic();
        let xs = gg.sample_n(&mut rng, 300_000);
        let (m, v, k) = moments(&xs);
        assert!(m.abs() < 0.01, "mean={m}");
        assert!((v - gg.variance()).abs() < 0.01, "var={v} want={}", gg.variance());
        assert!(k < -0.4, "should be sub-Gaussian, kurtosis={k}");
    }

    #[test]
    fn generalized_gaussian_beta2_matches_gaussian() {
        // β=2, α=√2 is exactly N(0,1).
        let mut rng = Pcg64::new(4);
        let gg = GeneralizedGaussian { alpha: std::f64::consts::SQRT_2, beta: 2.0 };
        let xs = gg.sample_n(&mut rng, 300_000);
        let (m, v, k) = moments(&xs);
        assert!(m.abs() < 0.01);
        assert!((v - 1.0).abs() < 0.02, "var={v}");
        assert!(k.abs() < 0.1, "kurtosis={k}");
    }

    #[test]
    fn mixture_variance_and_kurtosis() {
        let mut rng = Pcg64::new(5);
        let gm = GaussianMixture { alpha: 0.5, sigma: 0.1 };
        let xs = gm.sample_n(&mut rng, 400_000);
        let (m, v, k) = moments(&xs);
        // Var = α·1 + (1-α)·σ² = 0.505
        assert!(m.abs() < 0.01);
        assert!((v - 0.505).abs() < 0.01, "var={v}");
        // 4th moment = 3(α + (1-α)σ⁴) = 3·0.50005 ⇒ kurtosis ≈ 2.88
        assert!((k - 2.88).abs() < 0.2, "kurtosis={k}");
    }

    #[test]
    fn mixture_alpha_one_is_standard_normal() {
        let mut rng = Pcg64::new(6);
        let gm = GaussianMixture { alpha: 1.0, sigma: 0.1 };
        let xs = gm.sample_n(&mut rng, 200_000);
        let (_, v, k) = moments(&xs);
        assert!((v - 1.0).abs() < 0.02);
        assert!(k.abs() < 0.1);
    }
}

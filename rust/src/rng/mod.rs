//! Pseudo-random number generation substrate.
//!
//! The offline crate registry has no `rand`, so we carry our own PRNG and
//! the distributions the paper's experiments need. Everything is
//! deterministic given a seed — the figures are medians over many seeded
//! runs and must be exactly reproducible.

mod pcg;
mod distributions;

pub use distributions::{
    GaussianMixture, GeneralizedGaussian, Laplace, Normal, Sample, Uniform,
};
pub use pcg::Pcg64;

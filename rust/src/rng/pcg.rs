//! PCG64 (XSL-RR 128/64) pseudo-random generator.
//!
//! Reference: O'Neill, "PCG: A Family of Simple Fast Space-Efficient
//! Statistically Good Algorithms for Random Number Generation" (2014).
//! This is the same generator family NumPy uses for `default_rng`.

/// PCG XSL-RR 128/64: 128-bit LCG state, 64-bit xorshift-rotate output.
#[derive(Clone, Debug)]
pub struct Pcg64 {
    state: u128,
    inc: u128,
}

const PCG_MULT: u128 = 0x2360_ed05_1fc6_5da4_4385_df64_9fcc_f645;

impl Pcg64 {
    /// Create a generator from a seed. Two generators with different seeds
    /// produce independent-looking streams; same seed ⇒ same stream.
    pub fn new(seed: u64) -> Self {
        // SplitMix64 to expand the seed into state + increment, so that
        // consecutive seeds give well-separated streams.
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9e37_79b9_7f4a_7c15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xbf58_476d_1ce4_e5b9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94d0_49bb_1331_11eb);
            z ^ (z >> 31)
        };
        let state = ((next() as u128) << 64) | next() as u128;
        let inc = (((next() as u128) << 64) | next() as u128) | 1;
        let mut rng = Self { state, inc };
        // Advance once so the first output already mixes the increment.
        rng.next_u64();
        rng
    }

    /// Derive a child generator; used to hand independent streams to
    /// parallel jobs without sharing state.
    pub fn split(&mut self) -> Self {
        let s = self.next_u64();
        let t = self.next_u64();
        Self::new(s ^ t.rotate_left(32))
    }

    /// The next raw 64-bit output (PCG XSL-RR 128/64).
    #[inline]
    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_mul(PCG_MULT).wrapping_add(self.inc);
        let rot = (self.state >> 122) as u32;
        let xsl = ((self.state >> 64) as u64) ^ (self.state as u64);
        xsl.rotate_right(rot)
    }

    /// Uniform double in [0, 1) with 53 random bits.
    #[inline]
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform double in the open interval (0, 1); safe for log().
    #[inline]
    pub fn next_f64_open(&mut self) -> f64 {
        ((self.next_u64() >> 11) + 1) as f64 * (1.0 / ((1u64 << 53) as f64 + 1.0))
    }

    /// Uniform integer in [0, n) without modulo bias (Lemire's method).
    pub fn next_below(&mut self, n: u64) -> u64 {
        debug_assert!(n > 0, "next_below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut lo = m as u64;
        if lo < n {
            let t = n.wrapping_neg() % n;
            while lo < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                lo = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Fisher–Yates shuffle of a slice.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.next_below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// A random permutation of 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut p: Vec<usize> = (0..n).collect();
        self.shuffle(&mut p);
        p
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_given_seed() {
        let mut a = Pcg64::new(42);
        let mut b = Pcg64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let mut a = Pcg64::new(1);
        let mut b = Pcg64::new(2);
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }

    #[test]
    fn f64_in_unit_interval() {
        let mut rng = Pcg64::new(7);
        for _ in 0..10_000 {
            let x = rng.next_f64();
            assert!((0.0..1.0).contains(&x));
            let y = rng.next_f64_open();
            assert!(y > 0.0 && y < 1.0);
        }
    }

    #[test]
    fn uniform_mean_and_var() {
        let mut rng = Pcg64::new(3);
        let n = 200_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_f64()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 5e-3, "mean={mean}");
        assert!((var - 1.0 / 12.0).abs() < 5e-3, "var={var}");
    }

    #[test]
    fn next_below_is_in_range_and_roughly_uniform() {
        let mut rng = Pcg64::new(11);
        let n = 10u64;
        let mut counts = [0usize; 10];
        for _ in 0..100_000 {
            let x = rng.next_below(n);
            assert!(x < n);
            counts[x as usize] += 1;
        }
        for c in counts {
            assert!((c as f64 - 10_000.0).abs() < 600.0, "count={c}");
        }
    }

    #[test]
    fn shuffle_is_a_permutation() {
        let mut rng = Pcg64::new(5);
        let mut xs: Vec<usize> = (0..100).collect();
        rng.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..100).collect::<Vec<_>>());
        assert_ne!(xs, (0..100).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn split_streams_are_independent_looking() {
        let mut root = Pcg64::new(9);
        let mut a = root.split();
        let mut b = root.split();
        let same = (0..64).filter(|_| a.next_u64() == b.next_u64()).count();
        assert!(same < 2);
    }
}

#!/usr/bin/env python3
"""Toolchain-less mirror of the fica-lint / fica-audit engine.

This is a 1:1 port of ``tools/fica-lint/src/{lib,items,audit,main}.rs``:
same scanner, same nine rules, same waiver engine, same workspace
model, same report — byte-for-byte, which the CI parity gate proves by
diffing ``mirror.py --json`` against ``cargo run -p fica-lint -- --json``
over the whole tree. Keep the two in lockstep: every semantic change
lands in both implementations in the same commit.

Usage (mirrors the Rust CLI, plus one extra mode):

    mirror.py [--root DIR] [--json] [--self]
    mirror.py [--json] --lint-file REL PATH   # single-file fixture mode

Exit status: 0 clean (no unwaived violations), 1 violations found,
2 usage or I/O error.
"""

import os
import sys

RULES = [
    "no-panic",
    "float-accum",
    "nondeterminism",
    "fail-closed",
    "unchecked-arith",
    "lock-hygiene",
    "schema-drift",
    "contract-coverage",
    "stale-waiver",
]

WAIVABLE = RULES[:6]

SANCTIONED_FNS = ["fold_lanes", "tree_reduce", "combine", "combine_vec", "absorb", "update", "partial"]

DECODER_NAMES = ["parse", "decode", "open", "read", "load", "from_bytes", "next_chunk"]

SIZE_MARKERS = [
    "bytes", "cap", "chunk", "cols", "count", "idx", "len", "n", "nbytes", "off", "offset", "pos",
    "rows", "size", "stride", "written",
]

CHANNEL_METHODS = ["recv", "recv_timeout", "send", "send_timeout", "try_recv", "try_send"]

PANIC_MACROS = ["panic", "assert", "unreachable", "todo", "unimplemented"]

CONTRACT_HEADER = "| paths compared | guarantee | why | pinned by |"


def is_digit(c):
    return "0" <= c <= "9"


def is_ident(c):
    return c.isalnum() or c == "_"


def is_ascii_ident(c):
    return ("a" <= c <= "z") or ("A" <= c <= "Z") or is_digit(c) or c == "_"


def blank(out, a, b):
    for k in range(a, min(b, len(out))):
        if out[k] != "\n":
            out[k] = " "


def find_chars(hay, start, needle):
    if not needle or len(hay) < len(needle):
        return None
    at = "".join(hay).find("".join(needle), start)
    return None if at < 0 else at


def strip_source(src):
    """-> (code: list[char], comments: [(off, text)], strings: [(off, content)])."""
    s = list(src)
    n = len(s)
    out = list(s)
    comments = []
    strings = []
    i = 0
    while i < n:
        c = s[i]
        nxt = s[i + 1] if i + 1 < n else "\0"
        if c == "/" and nxt == "/":
            j = i
            while j < n and s[j] != "\n":
                j += 1
            comments.append((i, "".join(s[i:j])))
            blank(out, i, j)
            i = j
        elif c == "/" and nxt == "*":
            depth = 1
            j = i + 2
            while j < n and depth > 0:
                if s[j] == "/" and j + 1 < n and s[j + 1] == "*":
                    depth += 1
                    j += 2
                elif s[j] == "*" and j + 1 < n and s[j + 1] == "/":
                    depth -= 1
                    j += 2
                else:
                    j += 1
            comments.append((i, "".join(s[i:j])))
            blank(out, i, j)
            i = j
        elif c == '"':
            j = i + 1
            while j < n:
                if s[j] == "\\":
                    j += 2
                elif s[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            content_end = max(j - 1, i + 1)
            strings.append((i + 1, "".join(s[i + 1 : min(content_end, n)])))
            blank(out, i + 1, content_end)
            i = j
        elif c in ("r", "b") and (i == 0 or not is_ident(s[i - 1])):
            # Raw string r"..." / r#"..."# / byte string b"..." / br#"..."#.
            j = i + 1
            raw = c == "r"
            if c == "b" and j < n and s[j] == "r":
                raw = True
                j += 1
            hashes = 0
            while j < n and s[j] == "#":
                hashes += 1
                j += 1
            if raw and j < n and s[j] == '"':
                j += 1
                end = list('"' + "#" * hashes)
                k = find_chars(s, j, end)
                k = n if k is None else k + len(end)
                content_end = max(k - min(len(end), k), i + 1)
                if c == "r":
                    strings.append((j, "".join(s[j : min(content_end, n)])))
                blank(out, i + 1, content_end)
                i = k
            elif (not raw) and hashes == 0 and j < n and s[j] == '"':
                # b"..." — same escape rules as a normal string.
                j += 1
                while j < n:
                    if s[j] == "\\":
                        j += 2
                    elif s[j] == '"':
                        j += 1
                        break
                    else:
                        j += 1
                blank(out, i + 2, max(j - 1, i + 2))
                i = j
            else:
                i += 1
        elif c == "'":
            # Char literal vs lifetime.
            if nxt == "\\":
                j = i + 2
                while j < n and s[j] != "'":
                    j += 1
                j += 1
                blank(out, i + 1, max(j - 1, i + 1))
                i = j
            elif i + 2 < n and s[i + 2] == "'" and nxt != "'":
                blank(out, i + 1, i + 2)
                i += 3
            else:
                i += 1  # lifetime
        else:
            i += 1
    return out, comments, strings


def line_of(code, off):
    return sum(1 for c in code[: min(off, len(code))] if c == "\n") + 1


def line_bounds(code, lineno):
    start = 0
    line = 1
    for i, c in enumerate(code):
        if line == lineno and c == "\n":
            return start, i
        if c == "\n":
            line += 1
            start = i + 1
    return start, len(code)


def match_brace(code, open_idx):
    depth = 0
    for j in range(open_idx, len(code)):
        if code[j] == "{":
            depth += 1
        elif code[j] == "}":
            depth -= 1
            if depth == 0:
                return j + 1
    return len(code)


def blank_cfg_test(code):
    attr = list("#[cfg(test)]")
    starts = []
    frm = 0
    while True:
        i = find_chars(code, frm, attr)
        if i is None:
            break
        starts.append(i)
        frm = i + len(attr)
    regions = []
    for start in starts:
        j = start + len(attr)
        while j < len(code) and code[j] != "{" and code[j] != ";":
            j += 1
        end = match_brace(code, j) if (j < len(code) and code[j] == "{") else j + 1
        upper = min(end, len(code))
        blank(code, start, upper)
        regions.append((start, upper))
    return regions


class Waiver:
    def __init__(self, rules, line_start, line_end, line, span, file_wide):
        self.rules = rules
        self.line_start = line_start
        self.line_end = line_end
        self.line = line
        self.span = span
        self.file_wide = file_wide
        self.used = [False] * len(rules)


class Waivers:
    def __init__(self):
        self.scoped = []
        self.file_wide = []
        self.lock_orders = []  # (names, line, span)
        self.bad = []  # (line, span, msg)


def parse_directive(text):
    at = text.find("fica-lint:")
    if at < 0:
        return None
    rest = text[at + len("fica-lint:") :].lstrip()
    if rest.startswith("lock-order"):
        rest = rest[len("lock-order") :]
        if not rest.startswith("("):
            return None
        rest = rest[1:]
        close = rest.find(")")
        if close < 0:
            return None
        return ("lock-order", rest[:close], None)
    if not rest.startswith("allow"):
        return None
    rest = rest[len("allow") :]
    file_wide = False
    if rest.startswith("-file"):
        file_wide = True
        rest = rest[len("-file") :]
    if not rest.startswith("("):
        return None
    rest = rest[1:]
    close = rest.find(")")
    if close < 0:
        return None
    rules_raw = rest[:close]
    just = rest[close + 1 :].strip()
    for dash in ["—", "–", "--", "-"]:
        if just.startswith(dash):
            just = just[len(dash) :].lstrip()
            break
    return ("allow-file" if file_wide else "allow", rules_raw, just)


def scan_waivers(code, comments):
    w = Waivers()
    for off, text in comments:
        lineno = line_of(code, off)
        span = (off, off + len(text))
        d = parse_directive(text)
        if d is None:
            continue
        kind, raw, just = d
        if kind == "lock-order":
            names = [r.strip() for r in raw.split(",") if r.strip()]
            if not names:
                w.bad.append((lineno, span, "lock-order declaration names no locks"))
            else:
                w.lock_orders.append((names, lineno, span))
            continue
        rules = sorted(set(r.strip() for r in raw.split(",") if r.strip()))
        if not rules or not all(r in WAIVABLE for r in rules):
            w.bad.append(
                (lineno, span, "waiver names unknown or unwaivable rule(s): %s" % raw.strip())
            )
            continue
        if not just:
            w.bad.append((lineno, span, "waiver without justification"))
            continue
        if kind == "allow-file":
            w.file_wide.append(Waiver(rules, 0, 1 << 62, lineno, span, True))
            continue
        ls, le = line_bounds(code, lineno)
        trailing = any(not c.isspace() for c in code[ls : min(off, len(code))])
        if trailing:
            # Trailing waiver: covers its own line.
            w.scoped.append(Waiver(rules, lineno, lineno, lineno, span, False))
            continue
        # Standalone: covers the next statement-or-item (depth <= 0 close,
        # matching the Rust engine — see lib.rs for why).
        j = le + 1
        while j < len(code) and code[j].isspace():
            j += 1
        depth = 0
        end = len(code)
        k = j
        while k < len(code):
            ch = code[k]
            if ch == "{":
                depth += 1
            elif ch == "}":
                depth -= 1
                if depth <= 0:
                    end = k + 1
                    break
            elif ch == ";" and depth <= 0:
                end = k + 1
                break
            k += 1
        w.scoped.append(
            Waiver(
                rules,
                line_of(code, j),
                line_of(code, min(end, max(len(code) - 1, 0))),
                lineno,
                span,
                False,
            )
        )
    return w


def fn_ranges(code):
    out = []
    i = 0
    n = len(code)
    while i < n:
        if (
            code[i] == "f"
            and i + 1 < n
            and code[i + 1] == "n"
            and (i == 0 or not is_ascii_ident(code[i - 1]))
            and (i + 2 >= n or not is_ascii_ident(code[i + 2]))
        ):
            j = i + 2
            ws_start = j
            while j < n and code[j].isspace():
                j += 1
            if j > ws_start and j < n and is_ascii_ident(code[j]):
                name_start = j
                while j < n and is_ascii_ident(code[j]):
                    j += 1
                name = "".join(code[name_start:j])
                while j < n and code[j] != "{" and code[j] != ";":
                    j += 1
                if j < n and code[j] == "{":
                    out.append((name, i, match_brace(code, j)))
        i += 1
    return out


def enclosing_fn(ranges, off):
    best = None
    for name, a, b in ranges:
        if a <= off < b and (best is None or a > best[1]):
            best = (name, a)
    return best[0] if best else None


def is_int_literal(s):
    for suf in ["u8", "u16", "u32", "u64", "usize", "i8", "i16", "i32", "i64", "isize"]:
        if s.endswith(suf):
            s = s[: -len(suf)]
            break
    return bool(s) and is_digit(s[0]) and all(is_digit(c) or c == "_" for c in s[1:])


def ident_at(code, i):
    j = i
    while j < len(code) and is_ascii_ident(code[j]):
        j += 1
    return j, "".join(code[i:j])


def skip_ws(code, i):
    while i < len(code) and code[i].isspace():
        i += 1
    return i


def viol(code, start, end, rule, msg):
    return {
        "path": "",
        "line": line_of(code, start),
        "span": (start, end),
        "rule": rule,
        "msg": msg,
        "waived": False,
    }


def rule_no_panic(code, sink):
    n = len(code)
    i = 0
    while i < n:
        if code[i] == ".":
            j = skip_ws(code, i + 1)
            k, name = ident_at(code, j)
            kk = skip_ws(code, k)
            if name in ("unwrap", "expect") and kk < n and code[kk] == "(":
                sink.append(
                    viol(code, i, k, "no-panic", "`.%s()` in library code — use a typed `IcaError` path" % name)
                )
        if is_ascii_ident(code[i]) and (i == 0 or not is_ascii_ident(code[i - 1])):
            j, name = ident_at(code, i)
            if name in PANIC_MACROS and j < n and code[j] == "!":
                k = skip_ws(code, j + 1)
                if k < n and code[k] in "([{":
                    sink.append(
                        viol(code, i, j + 1, "no-panic", "`%s!` in library code — use `debug_assert!` or a typed error" % name)
                    )
            i = j
            continue
        i += 1


def rule_float_accum(code, ranges, sink):
    n = len(code)
    i = 0
    while i + 1 < n:
        if code[i] == "+" and code[i + 1] == "=":
            _, le = line_bounds(code, line_of(code, i))
            rhs = "".join(code[min(i + 2, le) : le]).strip().rstrip(";").strip()
            fn = enclosing_fn(ranges, i)
            sanctioned = fn is not None and fn in SANCTIONED_FNS
            if not is_int_literal(rhs) and not sanctioned:
                sink.append(
                    viol(code, i, i + 2, "float-accum", "raw `+=` accumulation outside sanctioned reduction helpers")
                )
            i += 2
            continue
        if code[i] == ".":
            j = skip_ws(code, i + 1)
            name_end, name = ident_at(code, j)
            if name == "sum":
                k = skip_ws(code, name_end)
                # Optional turbofish `::<T>`.
                if k + 1 < n and code[k] == ":" and code[k + 1] == ":":
                    t = skip_ws(code, k + 2)
                    if t < n and code[t] == "<":
                        gt = None
                        for p in range(t, n):
                            if code[p] == ">":
                                gt = p
                                break
                        if gt is not None:
                            k = skip_ws(code, gt + 1)
                if k < n and code[k] == "(":
                    fn = enclosing_fn(ranges, i)
                    sanctioned = fn is not None and fn in SANCTIONED_FNS
                    if not sanctioned:
                        sink.append(
                            viol(code, i, name_end, "float-accum", "`.sum()` reduction outside sanctioned helpers — order must be pinned")
                        )
        i += 1


def rule_nondeterminism(code, sink):
    i = 0
    while i < len(code):
        if is_ascii_ident(code[i]) and (i == 0 or not is_ascii_ident(code[i - 1])):
            j, name = ident_at(code, i)
            if name == "HashMap":
                sink.append(
                    viol(code, i, j, "nondeterminism", "`HashMap` on a solver path — use `BTreeMap` or waive (lookup-only)")
                )
            elif name in ("SystemTime", "Instant"):
                sink.append(
                    viol(code, i, j, "nondeterminism", "`%s` outside bench/ or obs/ — wall-clock on a solver path" % name)
                )
            i = j
            continue
        i += 1


def rule_fail_closed(code, sink):
    n = len(code)
    i = 0
    while i < n:
        if (
            code[i] == "p"
            and (i == 0 or not is_ascii_ident(code[i - 1]))
            and "".join(code[i : i + 3]) == "pub"
            and i + 3 < n
            and code[i + 3].isspace()
        ):
            j = skip_ws(code, i + 3)
            if "".join(code[j : j + 2]) == "fn" and j + 2 < n and code[j + 2].isspace():
                k = skip_ws(code, j + 2)
                name_end, name = ident_at(code, k)
                if name:
                    lower = name.lower()
                    if any(d in lower for d in DECODER_NAMES):
                        e = name_end
                        while e < n and code[e] != "{" and code[e] != ";":
                            e += 1
                        sig = "".join(code[i:e])
                        if "Result" not in sig:
                            sink.append(
                                viol(code, i, name_end, "fail-closed", "decoder `pub fn %s` must return `Result`" % name)
                            )
        i += 1


def marker_name(name):
    if not name:
        return False
    for m in SIZE_MARKERS:
        if name == m:
            return True
        if len(name) > len(m) + 1 and (
            (name.endswith(m) and name[len(name) - len(m) - 1] == "_")
            or (name.startswith(m) and name[len(m)] == "_")
        ):
            return True
    return False


def float_ident(name):
    return name in ("f32", "f64") or name.endswith("f32") or name.endswith("f64")


def left_operand(code, op):
    """-> (name, is_float, skip_op)."""
    p = op
    while p > 0 and code[p - 1].isspace():
        p -= 1
    if p == 0:
        return "", False, True
    last = code[p - 1]
    if last in (")", "]"):
        opn = "(" if last == ")" else "["
        depth = 1
        q = p - 1
        while q > 0:
            q -= 1
            if code[q] == last:
                depth += 1
            elif code[q] == opn:
                depth -= 1
                if depth == 0:
                    break
        if q > 0 and is_ascii_ident(code[q - 1]):
            s = q - 1
            while s > 0 and is_ascii_ident(code[s - 1]):
                s -= 1
            return "".join(code[s:q]), False, False
        return "", False, False
    if is_ascii_ident(last):
        s = p - 1
        while s > 0 and is_ascii_ident(code[s - 1]):
            s -= 1
        name = "".join(code[s:p])
        if s > 0 and code[s - 1] == "'":
            return "", False, True  # lifetime — type context
        if is_digit(name[0]):
            if float_ident(name) or (s > 1 and code[s - 1] == "." and is_digit(code[s - 2])):
                return "", True, False
            return "", False, False  # literal: never a size marker
        if float_ident(name):
            return "", True, False  # `as f64 *` — float arithmetic
        return name, False, False
    return "", False, False


def right_operand(code, after_op):
    """-> (name, is_float)."""
    n = len(code)
    q = skip_ws(code, after_op)
    if q >= n or not is_ascii_ident(code[q]):
        return "", False
    r, name = ident_at(code, q)
    if is_digit(name[0]):
        if float_ident(name) or (r + 1 < n and code[r] == "." and is_digit(code[r + 1])):
            return "", True
        return "", False
    if float_ident(name):
        return "", True
    # Chase the path to its decisive last segment: `self.n`, `chunk.cols()`.
    while True:
        t = skip_ws(code, r)
        if t < n and code[t] == ".":
            u = skip_ws(code, t + 1)
            if u < n and is_ascii_ident(code[u]):
                r2, seg = ident_at(code, u)
                if is_digit(seg[0]):
                    break  # tuple index — stop
                name = seg
                r = r2
                continue
        break
    return name, False


def rule_unchecked_arith(code, sink):
    n = len(code)
    for i in range(n):
        opch = code[i]
        if opch != "*" and opch != "+":
            continue
        if i + 1 < n and code[i + 1] == "=":
            continue  # compound assignment: float-accum's turf
        p = i
        while p > 0 and code[p - 1].isspace():
            p -= 1
        if p == 0:
            continue
        prev = code[p - 1]
        if not (is_ascii_ident(prev) or prev == ")" or prev == "]"):
            continue  # unary deref/plus, reference, range, cast, …
        lname, lfloat, lskip = left_operand(code, i)
        rname, rfloat = right_operand(code, i + 1)
        if lskip or lfloat or rfloat:
            continue
        if (lname and "A" <= lname[0] <= "Z") or (rname and "A" <= rname[0] <= "Z"):
            continue  # trait bound / type sum, not value arithmetic
        lm = marker_name(lname)
        rm = marker_name(rname)
        fires = (lm or rm) if opch == "*" else (lm and rm)
        if fires:
            opword = "mul" if opch == "*" else "add"
            ls = lname if lname else "?"
            rs = rname if rname else "?"
            sink.append(
                viol(
                    code,
                    i,
                    i + 1,
                    "unchecked-arith",
                    "unchecked `%s` on size arithmetic (%s %s %s) — use checked_%s/saturating_%s or a waiver"
                    % (opch, ls, opch, rs, opword, opword),
                )
            )


def lock_sites(code):
    n = len(code)
    out = []
    i = 0
    while i < n:
        if code[i] != ".":
            i += 1
            continue
        j = skip_ws(code, i + 1)
        k, name = ident_at(code, j)
        kk = skip_ws(code, k)
        if name not in ("lock", "try_lock") or kk >= n or code[kk] != "(":
            i += 1
            continue
        # Mutex name: the ident (or call result) just before the dot.
        p = i
        while p > 0 and code[p - 1].isspace():
            p -= 1
        lock_name = ""
        if p > 0:
            last = code[p - 1]
            if last in (")", "]"):
                opn = "(" if last == ")" else "["
                depth = 1
                q = p - 1
                while q > 0:
                    q -= 1
                    if code[q] == last:
                        depth += 1
                    elif code[q] == opn:
                        depth -= 1
                        if depth == 0:
                            break
                if q > 0 and is_ascii_ident(code[q - 1]):
                    s = q - 1
                    while s > 0 and is_ascii_ident(code[s - 1]):
                        s -= 1
                    lock_name = "".join(code[s:q])
            elif is_ascii_ident(last):
                s = p - 1
                while s > 0 and is_ascii_ident(code[s - 1]):
                    s -= 1
                lock_name = "".join(code[s:p])
        # Binding: `let NAME = ….lock()…` extends the guard to the end
        # of the enclosing block (or `drop(NAME)`); an inline temporary
        # lives to the end of its statement.
        stmt_start = 0
        q = i
        while q > 0:
            q -= 1
            if code[q] in (";", "{", "}"):
                stmt_start = q + 1
                break
        s0 = skip_ws(code, stmt_start)
        after_let, kw = ident_at(code, s0)
        binding = None
        if kw == "let":
            b0 = skip_ws(code, after_let)
            b1, b = ident_at(code, b0)
            if b == "mut":
                b2 = skip_ws(code, b1)
                _, b = ident_at(code, b2)
            binding = b
        end = n
        depth = 0
        m = k
        while m < n:
            ch = code[m]
            if ch == "{":
                depth += 1
            elif ch == "}":
                if depth == 0:
                    end = m
                    break
                depth -= 1
            elif ch == ";" and depth == 0 and binding is None:
                end = m
                break
            elif binding is not None:
                if is_ascii_ident(ch) and (m == 0 or not is_ascii_ident(code[m - 1])) and depth >= 0:
                    m2, word = ident_at(code, m)
                    if word == "drop":
                        a = skip_ws(code, m2)
                        if a < n and code[a] == "(":
                            _, arg = ident_at(code, skip_ws(code, a + 1))
                            if arg == binding:
                                end = m
                                break
                    m = m2
                    continue
            m += 1
        out.append({"dot": i, "name_end": k, "lock_name": lock_name, "end": end})
        i = k
    return out


def rule_lock_hygiene(code, orders, sink):
    sites = lock_sites(code)
    if not sites:
        for _names, _line, span in orders[1:]:
            sink.append(viol(code, span[0], span[1], "lock-hygiene", "duplicate lock-order declaration"))
        return
    if not orders:
        first = sites[0]
        sink.append(
            viol(
                code,
                first["dot"],
                first["name_end"],
                "lock-hygiene",
                "file acquires locks but declares no canonical order — add a lock-order header comment",
            )
        )
        return
    for _names, _line, span in orders[1:]:
        sink.append(viol(code, span[0], span[1], "lock-hygiene", "duplicate lock-order declaration"))
    order = orders[0][0]

    def idx_of(name):
        return order.index(name) if name in order else None

    for site in sites:
        if idx_of(site["lock_name"]) is None:
            sink.append(
                viol(
                    code,
                    site["dot"],
                    site["name_end"],
                    "lock-hygiene",
                    "lock `%s` is not in the declared lock-order" % site["lock_name"],
                )
            )
    n = len(code)
    for outer in sites:
        # Channel traffic while the guard is live.
        j = outer["name_end"]
        while j < min(outer["end"], n):
            if code[j] != ".":
                j += 1
                continue
            a = skip_ws(code, j + 1)
            b, m = ident_at(code, a)
            bb = skip_ws(code, b)
            if m in CHANNEL_METHODS and bb < n and code[bb] == "(":
                sink.append(
                    viol(
                        code,
                        j,
                        b,
                        "lock-hygiene",
                        "channel `.%s()` while holding lock `%s` — drop the guard first" % (m, outer["lock_name"]),
                    )
                )
            j = max(b, j + 1)
        # Nested acquisition against the declared order.
        for inner in sites:
            if inner["dot"] <= outer["dot"] or inner["dot"] >= outer["end"]:
                continue
            oi = idx_of(outer["lock_name"])
            ii = idx_of(inner["lock_name"])
            if oi is not None and ii is not None and ii <= oi:
                sink.append(
                    viol(
                        code,
                        inner["dot"],
                        inner["name_end"],
                        "lock-hygiene",
                        "lock `%s` acquired while holding `%s` violates the declared lock-order"
                        % (inner["lock_name"], outer["lock_name"]),
                    )
                )


def apply_waivers(violations, waivers):
    for v in violations:
        hit = False
        for w in waivers.scoped:
            if w.line_start <= v["line"] <= w.line_end and v["rule"] in w.rules:
                v["waived"] = True
                w.used[w.rules.index(v["rule"])] = True
                hit = True
                break
        if hit:
            continue
        for w in waivers.file_wide:
            if v["rule"] in w.rules:
                v["waived"] = True
                w.used[w.rules.index(v["rule"])] = True
                break


def stale_violations(waivers, out):
    for w in waivers.scoped + waivers.file_wide:
        for ix, rule in enumerate(w.rules):
            if w.used[ix]:
                continue
            if w.file_wide:
                msg = "stale waiver: allow-file(%s) no longer suppresses anything in this file — delete it" % rule
            else:
                msg = "stale waiver: allow(%s) no longer suppresses anything at its site — delete it" % rule
            out.append({"path": "", "line": w.line, "span": w.span, "rule": "stale-waiver", "msg": msg, "waived": False})


def sort_key(v):
    return (v["path"], v["line"], v["span"][0], v["span"][1], v["rule"], v["msg"], v["waived"])


def lint_impl(rel, src, self_mode):
    code, comments, _strings = strip_source(src)
    waivers = scan_waivers(code, comments)
    blank_cfg_test(code)
    ranges = fn_ranges(code)
    sink = []

    rule_no_panic(code, sink)
    if self_mode:
        rule_fail_closed(code, sink)
    else:
        if rel.startswith("backend/") or rel.startswith("linalg/") or rel == "data/stats.rs":
            rule_float_accum(code, ranges, sink)
        if not (rel.startswith("bench/") or rel.startswith("obs/")):
            rule_nondeterminism(code, sink)
        if rel.startswith("data/") or rel.startswith("registry/") or rel == "util/json.rs":
            rule_fail_closed(code, sink)
        if (
            (rel.startswith("data/") and rel != "data/stats.rs")
            or rel == "util/json.rs"
            or rel.startswith("daemon/")
            or rel.startswith("registry/")
        ):
            rule_unchecked_arith(code, sink)
        if rel == "backend/pool.rs" or rel.startswith("coordinator/") or rel.startswith("daemon/"):
            rule_lock_hygiene(code, waivers.lock_orders, sink)

    apply_waivers(sink, waivers)
    for line, span, msg in waivers.bad:
        sink.append({"path": "", "line": line, "span": span, "rule": "bad-waiver", "msg": msg, "waived": False})
    stale_violations(waivers, sink)
    for v in sink:
        v["path"] = rel
    sink.sort(key=sort_key)
    return sink


def lint_file_full(rel, src):
    return lint_impl(rel, src, False)


def lint_file(rel, src):
    return [v for v in lint_file_full(rel, src) if not v["waived"]]


def lint_self_file(rel, src):
    return lint_impl(rel, src, True)


# ------------------------------------------------------------------ items

ITEM_KEYWORDS = {
    "fn": "fn",
    "struct": "struct",
    "enum": "enum",
    "trait": "trait",
    "impl": "impl",
    "mod": "mod",
    "use": "use",
    "const": "const",
    "static": "static",
    "type": "type",
}


def in_regions(regions, off):
    return any(a <= off < b for a, b in regions)


def item_end(code, frm, brace_bodied):
    n = len(code)
    j = frm
    while j < n:
        if code[j] == "{":
            if brace_bodied:
                return match_brace(code, j)
            j = match_brace(code, j)
        elif code[j] == ";":
            return j + 1
        else:
            j += 1
    return n


def impl_name(code, j):
    n = len(code)
    j = skip_ws(code, j)
    if j < n and code[j] == "<":
        depth = 0
        while j < n:
            if code[j] == "<":
                depth += 1
            elif code[j] == ">":
                depth -= 1
                if depth == 0:
                    j += 1
                    break
            j += 1
        j = skip_ws(code, j)
    k, name = ident_at(code, j)
    # `impl Trait for Type` — the item is named after Type.
    while True:
        w = skip_ws(code, k)
        if w < n and is_ascii_ident(code[w]):
            k2, word = ident_at(code, w)
            if word == "for":
                t = skip_ws(code, k2)
                k3, tyname = ident_at(code, t)
                if tyname:
                    name = tyname
                    k = k3
                break
        if w < n and (code[w] == ":" or code[w] == "<"):
            k = w + 1
            continue
        break
    return k, name


def scan_items(code, test_regions):
    n = len(code)
    out = []
    i = 0
    while i < n:
        if not is_ascii_ident(code[i]) or (i > 0 and is_ascii_ident(code[i - 1])):
            i += 1
            continue
        j, word = ident_at(code, i)
        kind = ITEM_KEYWORDS.get(word)
        if kind is None:
            i = j
            continue
        if kind == "impl":
            _, name = impl_name(code, j)
            if name:
                end = item_end(code, j, True)
                out.append({"kind": kind, "name": name, "start": i, "end": end, "in_test": in_regions(test_regions, i)})
        elif kind == "use":
            end = item_end(code, j, False)
            name = "".join(code[skip_ws(code, j) : max(end - 1, j)]).strip()
            if name:
                out.append({"kind": kind, "name": name, "start": i, "end": end, "in_test": in_regions(test_regions, i)})
        elif kind in ("const", "static"):
            # A const/static *item* always reads `const NAME :`.
            k = skip_ws(code, j)
            after, name = ident_at(code, k)
            if name == "mut":
                k2 = skip_ws(code, after)
                after, name = ident_at(code, k2)
            colon = skip_ws(code, after)
            if name and name != "fn" and colon < n and code[colon] == ":":
                end = item_end(code, after, False)
                out.append({"kind": kind, "name": name, "start": i, "end": end, "in_test": in_regions(test_regions, i)})
        else:
            k = skip_ws(code, j)
            if k > j:
                after, name = ident_at(code, k)
                if name:
                    end = item_end(code, after, True)
                    out.append({"kind": kind, "name": name, "start": i, "end": end, "in_test": in_regions(test_regions, i)})
        i = j
    return out


def scan_calls(code):
    not_calls = ["fn", "if", "while", "match", "for", "loop", "return", "in", "move"]
    n = len(code)
    out = []
    i = 0
    prev_word = ""
    while i < n:
        if is_ascii_ident(code[i]) and (i == 0 or not is_ascii_ident(code[i - 1])):
            j, word = ident_at(code, i)
            k = skip_ws(code, j)
            if (
                k < n
                and code[k] == "("
                and word not in not_calls
                and prev_word != "fn"
                and not is_digit(word[0])
            ):
                out.append((i, word))
            prev_word = word
            i = j
            continue
        i += 1
    return out


# ------------------------------------------------------------------ audit


def walk_tree(dirpath, prefix, exts, out):
    if not os.path.isdir(dirpath):
        return
    for name in sorted(os.listdir(dirpath)):
        path = os.path.join(dirpath, name)
        rel = "%s/%s" % (prefix, name)
        if os.path.isdir(path):
            walk_tree(path, rel, exts, out)
        elif os.path.splitext(name)[1] in ["." + e for e in exts]:
            with open(path, encoding="utf-8") as fh:
                out[rel] = fh.read()


def load_workspace(root):
    if not os.path.isdir(os.path.join(root, "rust", "src")):
        raise RuntimeError("%s has no rust/src — not a faster-ica workspace root" % root)
    files = {}
    walk_tree(os.path.join(root, "rust", "src"), "rust/src", ["rs"], files)
    walk_tree(os.path.join(root, "rust", "tests"), "rust/tests", ["rs", "json"], files)
    walk_tree(os.path.join(root, "docs"), "docs", ["md"], files)
    for top in ["ARCHITECTURE.md", "README.md"]:
        p = os.path.join(root, top)
        if os.path.isfile(p):
            with open(p, encoding="utf-8") as fh:
                files[top] = fh.read()
    return files


def discover_root(start):
    cur = os.path.abspath(start)
    while True:
        manifest = os.path.join(cur, "Cargo.toml")
        if os.path.isfile(manifest):
            with open(manifest, encoding="utf-8") as fh:
                if "[workspace]" in fh.read():
                    return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return None
        cur = parent


def scan_tags(chars):
    head = list("fica.")
    n = len(chars)
    out = []
    i = 0
    while i + len(head) < n:
        if chars[i : i + len(head)] != head or (i > 0 and is_ascii_ident(chars[i - 1])):
            i += 1
            continue
        j = i + len(head)
        fam_start = j
        while j < n and (("a" <= chars[j] <= "z") or is_digit(chars[j]) or chars[j] == "_"):
            j += 1
        if j == fam_start or j + 1 >= n or chars[j] != "/" or chars[j + 1] != "v":
            i += 1
            continue
        fam = "".join(chars[fam_start:j])
        k = j + 2
        digits_start = k
        ver = 0
        while k < n and is_digit(chars[k]):
            ver = ver * 10 + (ord(chars[k]) - ord("0"))
            k += 1
        if k == digits_start:
            i += 1
            continue
        out.append((i, k, fam, ver))
        i = k
    return out


def mk(path, chars, span, rule, msg):
    return {"path": path, "line": line_of(chars, span[0]), "span": span, "rule": rule, "msg": msg, "waived": False}


def backticked_idents(cell):
    chars = list(cell)
    out = []
    i = 0
    while i < len(chars):
        if chars[i] != "`":
            i += 1
            continue
        start = i + 1
        j = start
        while j < len(chars) and chars[j] != "`":
            j += 1
        if j >= len(chars):
            break
        tok = "".join(chars[start:j])
        if tok and all(is_ascii_ident(c) for c in tok):
            out.append(tok)
        i = j + 1
    return out


def rule_schema_drift(files, violations):
    code_versions = {}
    code_sites = []
    schema_consts = []
    for path in sorted(files):
        if not (path.startswith("rust/src/") and path.endswith(".rs")):
            continue
        src = files[path]
        code, _comments, strings = strip_source(src)
        erased = list(code)
        regions = blank_cfg_test(erased)
        tags_here = []
        for off, content in strings:
            if in_regions(regions, off):
                continue
            for a, b, fam, ver in scan_tags(list(content)):
                tags_here.append((off + a, off + b, fam, ver))
        for a, b, fam, ver in tags_here:
            code_versions.setdefault(fam, set()).add(ver)
            code_sites.append((path, (a, b), fam, ver))
        # Schema-named consts must carry a tag in their initializer.
        for item in scan_items(code, regions):
            if item["kind"] == "const" and not item["in_test"] and "_SCHEMA" in item["name"]:
                tagged = any(item["start"] <= a < item["end"] for a, _b, _f, _v in tags_here)
                schema_consts.append((path, (item["start"], item["end"]), item["name"], tagged))

    doc_tags = set()
    doc_sites = []
    for path in sorted(files):
        is_doc = (path.startswith("docs/") and path.endswith(".md")) or path in ("ARCHITECTURE.md", "README.md")
        if not is_doc:
            continue
        chars = list(files[path])
        for a, b, fam, ver in scan_tags(chars):
            doc_tags.add((fam, ver))
            doc_sites.append((path, (a, b), fam, ver))

    # (a) every code tag must be documented.
    for path, span, fam, ver in code_sites:
        if (fam, ver) not in doc_tags:
            chars = list(files[path])
            violations.append(
                mk(path, chars, span, "schema-drift",
                   "schema tag `fica.%s/v%d` in code is not documented under docs/ — update the schema docs" % (fam, ver))
            )
    # (b) no doc tag may outrun the code for a family the code writes.
    for path, span, fam, ver in doc_sites:
        if fam in code_versions:
            mx = max(code_versions[fam]) if code_versions[fam] else 0
            if ver > mx:
                chars = list(files[path])
                violations.append(
                    mk(path, chars, span, "schema-drift",
                       "documented schema tag `fica.%s/v%d` has no code writer (max code version is v%d) — docs and code have drifted" % (fam, ver, mx))
                )
    # (c) fixture tags must match a code tag exactly.
    for path in sorted(files):
        if not (path.startswith("rust/tests/fixtures/") and path.endswith(".json")):
            continue
        chars = list(files[path])
        for a, b, fam, ver in scan_tags(chars):
            known = fam in code_versions and ver in code_versions[fam]
            if not known:
                violations.append(
                    mk(path, chars, (a, b), "schema-drift",
                       "fixture schema tag `fica.%s/v%d` matches no code tag — regenerate or retire the fixture" % (fam, ver))
                )
    # (d) schema-named consts carry their tag.
    for path, span, name, tagged in schema_consts:
        if not tagged:
            chars = list(files[path])
            violations.append(
                mk(path, chars, span, "schema-drift",
                   "const `%s` is schema-named but contains no `fica.<family>/vN` tag" % name)
            )


def rule_contract_coverage(files, violations):
    index = {}
    for path in sorted(files):
        if not path.endswith(".rs"):
            continue
        in_tests_tree = path.startswith("rust/tests/")
        in_src_tree = path.startswith("rust/src/")
        if not in_tests_tree and not in_src_tree:
            continue
        src = files[path]
        raw = list(src)
        code, _comments, _strings = strip_source(src)
        erased = list(code)
        regions = blank_cfg_test(erased)
        for item in scan_items(code, regions):
            if item["kind"] != "fn":
                continue
            if in_src_tree and not item["in_test"]:
                continue
            body = "".join(raw[item["start"] : min(item["end"], len(raw))])
            index[item["name"]] = index.get(item["name"], "") + body + "\n"

    arch_path = "ARCHITECTURE.md"
    if arch_path not in files:
        violations.append(
            {"path": arch_path, "line": 1, "span": (0, 0), "rule": "contract-coverage",
             "msg": "ARCHITECTURE.md not found — the equivalence-contract table is the coverage anchor",
             "waived": False}
        )
        return
    arch = files[arch_path]
    chars = list(arch)
    header_off = None
    off = 0
    for line in arch.split("\n"):
        if line.strip() == CONTRACT_HEADER:
            header_off = off
            break
        off += len(line) + 1
    if header_off is None:
        violations.append(
            {"path": arch_path, "line": 1, "span": (0, 0), "rule": "contract-coverage",
             "msg": "equivalence-contract table header `%s` not found in ARCHITECTURE.md" % CONTRACT_HEADER,
             "waived": False}
        )
        return

    # Rows: contiguous `|`-prefixed lines after the header.
    tail = "".join(chars[header_off:])
    row_off = header_off
    first = True
    for line in tail.split("\n"):
        this_off = row_off
        row_off += len(line) + 1
        if first:
            first = False  # the header line itself
            continue
        trimmed = line.strip()
        if not trimmed.startswith("|"):
            break
        if all(c == "|" or c == "-" or c == ":" or c.isspace() for c in trimmed):
            continue  # separator
        span = (this_off, this_off + len(line))
        cells = [c.strip() for c in trimmed.strip("|").split("|")]
        if len(cells) < 4:
            violations.append(
                mk(arch_path, chars, span, "contract-coverage", "contract row is missing its `pinned by` cell")
            )
            continue
        label = cells[0].replace("`", "")
        pinned = backticked_idents(cells[3])
        if not pinned:
            violations.append(
                mk(arch_path, chars, span, "contract-coverage",
                   "contract row (%s) pins no test — name the covering test fns in its `pinned by` cell" % label)
            )
            continue
        resolved = ""
        for tok in pinned:
            if tok in index:
                resolved += index[tok]
            else:
                violations.append(
                    mk(arch_path, chars, span, "contract-coverage",
                       "contract row (%s) pins `%s` but no such test fn exists" % (label, tok))
                )
        if not resolved:
            continue  # every pin dangled; already reported
        for sym in backticked_idents(cells[0]):
            if sym not in resolved:
                violations.append(
                    mk(arch_path, chars, span, "contract-coverage",
                       "contract row (%s) is pinned by tests that never mention `%s`" % (label, sym))
                )


def audit(files):
    violations = []
    for path in sorted(files):
        if not (path.startswith("rust/src/") and path.endswith(".rs")):
            continue
        rel = path[len("rust/src/") :]
        for v in lint_file_full(rel, files[path]):
            v["path"] = path
            violations.append(v)
    rule_schema_drift(files, violations)
    rule_contract_coverage(files, violations)
    violations.sort(key=sort_key)
    return violations


def render_text(violations, nfiles):
    out = []
    n = 0
    for v in violations:
        if v["waived"]:
            continue
        out.append("%s:%d: [%s] %s\n" % (v["path"], v["line"], v["rule"], v["msg"]))
        n += 1
    if n > 0:
        out.append("fica-lint: %d violation(s)\n" % n)
    else:
        out.append("fica-lint: clean (%d files)\n" % nfiles)
    return "".join(out)


def json_escape(s):
    out = []
    for c in s:
        if c == '"':
            out.append('\\"')
        elif c == "\\":
            out.append("\\\\")
        elif c == "\n":
            out.append("\\n")
        elif c == "\r":
            out.append("\\r")
        elif c == "\t":
            out.append("\\t")
        elif ord(c) < 0x20:
            out.append("\\u%04x" % ord(c))
        else:
            out.append(c)
    return "".join(out)


def render_json(violations, nfiles):
    out = ['{"schema":"fica.lint/v1","files":%d,"violations":[' % nfiles]
    for ix, v in enumerate(violations):
        if ix > 0:
            out.append(",")
        out.append(
            '\n{"path":"%s","line":%d,"span":[%d,%d],"rule":"%s","waived":%s,"msg":"%s"}'
            % (
                json_escape(v["path"]),
                v["line"],
                v["span"][0],
                v["span"][1],
                v["rule"],
                "true" if v["waived"] else "false",
                json_escape(v["msg"]),
            )
        )
    out.append("]}\n" if not violations else "\n]}\n")
    return "".join(out)


# ------------------------------------------------------------------- main


def collect_rs_files(root):
    out = []
    for dirpath, dirnames, filenames in os.walk(root):
        dirnames.sort()
        for name in sorted(filenames):
            if name.endswith(".rs"):
                out.append(os.path.join(dirpath, name))
    out.sort()
    return out


def self_report(root):
    src_root = os.path.join(root, "tools", "fica-lint", "src")
    if not os.path.isdir(src_root):
        raise RuntimeError("%s not found — not the workspace root?" % src_root)
    files = collect_rs_files(src_root)
    violations = []
    for path in files:
        rel = os.path.relpath(path, src_root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        for v in lint_self_file(rel, src):
            v["path"] = "tools/fica-lint/src/%s" % rel
            violations.append(v)
    violations.sort(key=sort_key)
    return violations, len(files)


def main(argv):
    root = None
    as_json = False
    self_mode = False
    lint_one = None
    i = 0
    while i < len(argv):
        a = argv[i]
        if a == "--json":
            as_json = True
        elif a == "--self":
            self_mode = True
        elif a == "--root":
            i += 1
            if i >= len(argv):
                sys.stderr.write("fica-lint: error: --root needs a directory argument\n")
                return 2
            root = argv[i]
        elif a == "--lint-file":
            if i + 2 >= len(argv):
                sys.stderr.write("fica-lint: error: --lint-file needs REL and PATH arguments\n")
                return 2
            lint_one = (argv[i + 1], argv[i + 2])
            i += 2
        else:
            sys.stderr.write(
                "fica-lint: error: unknown argument %r (usage: mirror.py [--root DIR] [--json] [--self] [--lint-file REL PATH])\n" % a
            )
            return 2
        i += 1

    if lint_one is not None:
        rel, path = lint_one
        try:
            with open(path, encoding="utf-8") as fh:
                src = fh.read()
        except OSError as e:
            sys.stderr.write("fica-lint: error: %s\n" % e)
            return 2
        violations = lint_file_full(rel, src)
        sys.stdout.write(render_json(violations, 1) if as_json else render_text(violations, 1))
        return 0 if all(v["waived"] for v in violations) else 1

    if root is None:
        root = discover_root(os.getcwd())
        if root is None:
            sys.stderr.write(
                "fica-lint: error: no workspace root found (no ancestor Cargo.toml with [workspace]); pass --root\n"
            )
            return 2

    try:
        if self_mode:
            violations, nfiles = self_report(root)
        else:
            files = load_workspace(root)
            nfiles = len(files)
            violations = audit(files)
    except (RuntimeError, OSError) as e:
        sys.stderr.write("fica-lint: error: %s\n" % e)
        return 2
    sys.stdout.write(render_json(violations, nfiles) if as_json else render_text(violations, nfiles))
    return 0 if all(v["waived"] for v in violations) else 1


if __name__ == "__main__":
    sys.exit(main(sys.argv[1:]))

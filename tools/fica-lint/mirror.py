#!/usr/bin/env python3
"""Toolchain-less mirror of the `fica-lint` rule engine.

This script implements byte-for-byte the same semantics as the Rust
crate in `src/` (scanner, `#[cfg(test)]` skipping, waiver grammar and
scoping, rules R1-R4 + `bad-waiver`). It exists so the audit can be run
in environments without a Rust toolchain; the Rust crate is the
authoritative implementation and is what CI runs.

Usage: python3 mirror.py [ROOT]   (default ROOT = ../../rust/src)
Exit status: 0 if no unwaived violations, 1 otherwise.
"""

import os
import re
import sys

RULES = ("no-panic", "float-accum", "nondeterminism", "fail-closed")
SANCTIONED_FNS = {
    # the fixed-order lane fold and pairwise tree reduction (backend/)
    "fold_lanes", "tree_reduce", "combine", "combine_vec",
    # the StreamingStats moment accumulators (data/stats.rs)
    "absorb", "update", "partial",
}
DECODER_NAMES = ("parse", "decode", "open", "read", "load", "from_bytes", "next_chunk")


def is_ident(c):
    return c.isalnum() or c == "_"


def strip_source(src):
    """Blank comments and string/char-literal contents, preserving length
    and newlines. Returns (code, comments) where comments is a list of
    (byte_offset, text)."""
    n = len(src)
    out = list(src)
    comments = []
    i = 0

    def blank(a, b):
        for k in range(a, b):
            if out[k] != "\n":
                out[k] = " "

    while i < n:
        c = src[i]
        nxt = src[i + 1] if i + 1 < n else ""
        if c == "/" and nxt == "/":
            j = i
            while j < n and src[j] != "\n":
                j += 1
            comments.append((i, src[i:j]))
            blank(i, j)
            i = j
        elif c == "/" and nxt == "*":
            depth = 1
            j = i + 2
            while j < n and depth > 0:
                if src[j] == "/" and j + 1 < n and src[j + 1] == "*":
                    depth += 1
                    j += 2
                elif src[j] == "*" and j + 1 < n and src[j + 1] == "/":
                    depth -= 1
                    j += 2
                else:
                    j += 1
            comments.append((i, src[i:j]))
            blank(i, j)
            i = j
        elif c == '"':
            j = i + 1
            while j < n:
                if src[j] == "\\":
                    j += 2
                elif src[j] == '"':
                    j += 1
                    break
                else:
                    j += 1
            blank(i + 1, max(i + 1, j - 1))
            i = j
        elif c in ("r", "b") and (i == 0 or not is_ident(src[i - 1])):
            # raw string r"..." / r#"..."# / byte string b"..." / br#"..."#
            j = i + 1
            raw = c == "r"
            if c == "b" and j < n and src[j] == "r":
                raw = True
                j += 1
            hashes = 0
            while j < n and src[j] == "#":
                hashes += 1
                j += 1
            if raw and j < n and src[j] == '"':
                j += 1
                end = '"' + "#" * hashes
                k = src.find(end, j)
                k = n if k == -1 else k + len(end)
                blank(i + 1, max(i + 1, k - len(end)))
                i = k
            elif not raw and hashes == 0 and j < n and src[j] == '"':
                # b"..." — same escape rules as a normal string
                j += 1
                while j < n:
                    if src[j] == "\\":
                        j += 2
                    elif src[j] == '"':
                        j += 1
                        break
                    else:
                        j += 1
                blank(i + 2, max(i + 2, j - 1))
                i = j
            else:
                i += 1
        elif c == "'":
            # char literal vs lifetime
            if nxt == "\\":
                j = i + 2
                while j < n and src[j] != "'":
                    j += 1
                j += 1
                blank(i + 1, max(i + 1, j - 1))
                i = j
            elif i + 2 < n and src[i + 2] == "'" and nxt != "'":
                blank(i + 1, i + 2)
                i = i + 3
            else:
                i += 1  # lifetime
        else:
            i += 1
    return "".join(out), comments


def line_of(src, off):
    return src.count("\n", 0, off) + 1


def line_bounds(code, lineno):
    """(start_offset, end_offset) of a 1-based line in code."""
    lines = code.split("\n")
    start = sum(len(l) + 1 for l in lines[: lineno - 1])
    return start, start + len(lines[lineno - 1])


def match_brace(code, open_idx):
    """Index just past the `}` matching the `{` at open_idx (or len)."""
    depth = 0
    for j in range(open_idx, len(code)):
        if code[j] == "{":
            depth += 1
        elif code[j] == "}":
            depth -= 1
            if depth == 0:
                return j + 1
    return len(code)


def blank_cfg_test(code):
    """Blank every item annotated #[cfg(test)] (to its closing brace or `;`)."""
    out = list(code)
    for m in re.finditer(r"#\[cfg\(test\)\]", code):
        j = m.end()
        # skip further attributes / whitespace / keywords up to `{` or `;`
        while j < len(code) and code[j] not in "{;":
            j += 1
        end = match_brace(code, j) if j < len(code) and code[j] == "{" else j + 1
        for k in range(m.start(), min(end, len(code))):
            if out[k] != "\n":
                out[k] = " "
    return "".join(out)


WAIVER_RE = re.compile(r"fica-lint:\s*allow(-file)?\(([^)]*)\)\s*(.*)", re.S)


def parse_waivers(code, comments):
    """Returns (waivers, file_waivers, bad) where waivers is a list of
    (rule_set, line_start, line_end), file_waivers a set of rules, and
    bad a list of (line, msg) for waivers lacking a justification."""
    waivers, file_waivers, bad = [], set(), []
    for off, text in comments:
        m = WAIVER_RE.search(text)
        if not m:
            continue
        lineno = line_of(code, off)
        rules = {r.strip() for r in m.group(2).split(",") if r.strip()}
        just = m.group(3).strip()
        just = re.sub(r"^(—|–|--|-)\s*", "", just, count=1)
        if not rules or not rules <= set(RULES):
            bad.append((lineno, "waiver names unknown rule(s): %s" % m.group(2).strip()))
            continue
        if not just:
            bad.append((lineno, "waiver without justification"))
            continue
        if m.group(1):  # allow-file
            file_waivers |= rules
            continue
        ls, le = line_bounds(code, lineno)
        before = code[ls:off]
        if before.strip():  # trailing waiver: covers its own line
            waivers.append((rules, lineno, lineno))
        else:  # standalone: covers the next statement-or-item
            j = le + 1
            while j < len(code) and code[j].isspace():
                j += 1
            depth = 0
            end = len(code)
            k = j
            while k < len(code):
                ch = code[k]
                if ch == "{":
                    depth += 1
                elif ch == "}":
                    # depth 1→0 closes the statement's own brace group;
                    # depth 0→-1 closes the *enclosing* block (the waived
                    # code was a tail expression) — both end the scope.
                    depth -= 1
                    if depth <= 0:
                        end = k + 1
                        break
                elif ch == ";" and depth <= 0:
                    end = k + 1
                    break
                k += 1
            waivers.append((rules, line_of(code, j), line_of(code, min(end, len(code) - 1))))
    return waivers, file_waivers, bad


def fn_ranges(code):
    """[(name, start, end)] for every `fn name ... { ... }`."""
    out = []
    for m in re.finditer(r"\bfn\s+([A-Za-z0-9_]+)", code):
        j = m.end()
        while j < len(code) and code[j] not in "{;":
            j += 1
        if j < len(code) and code[j] == "{":
            out.append((m.group(1), m.start(), match_brace(code, j)))
    return out


def enclosing_fn(ranges, off):
    best = None
    for name, a, b in ranges:
        if a <= off < b and (best is None or a > best[1]):
            best = (name, a)
    return best[0] if best else None


INT_LIT_RE = re.compile(r"^\d[\d_]*(u(8|16|32|64|size)|i(8|16|32|64|size))?$")


def lint_file(rel, src):
    code0, comments = strip_source(src)
    waivers, file_waivers, bad = parse_waivers(code0, comments)
    code = blank_cfg_test(code0)
    ranges = fn_ranges(code)
    viol = []  # (line, rule, msg)

    def report(off, rule, msg):
        viol.append((line_of(code, off), rule, msg))

    # R1 no-panic — whole tree
    for m in re.finditer(r"\.\s*(unwrap|expect)\s*\(", code):
        report(m.start(), "no-panic", "`.%s()` in library code — use a typed `IcaError` path" % m.group(1))
    for m in re.finditer(r"(?<![A-Za-z0-9_])(panic|assert|unreachable|todo|unimplemented)!\s*[\(\[{]", code):
        report(m.start(), "no-panic", "`%s!` in library code — use `debug_assert!` or a typed error" % m.group(1))

    # R2 float-accum — backend/, linalg/, data/stats.rs
    if rel.startswith(("backend/", "linalg/")) or rel == "data/stats.rs":
        for m in re.finditer(r"\+=", code):
            ls, le = line_bounds(code, line_of(code, m.start()))
            rhs = code[m.end():le].strip().rstrip(";").strip()
            if INT_LIT_RE.match(rhs):
                continue
            fname = enclosing_fn(ranges, m.start())
            if fname in SANCTIONED_FNS:
                continue
            report(m.start(), "float-accum", "raw `+=` accumulation outside sanctioned reduction helpers")
        for m in re.finditer(r"\.\s*sum\s*(::\s*<[^>]*>\s*)?\(", code):
            fname = enclosing_fn(ranges, m.start())
            if fname in SANCTIONED_FNS:
                continue
            report(m.start(), "float-accum", "`.sum()` reduction outside sanctioned helpers — order must be pinned")

    # R3 nondeterminism — everywhere except bench/ and obs/
    if not (rel.startswith("bench/") or rel.startswith("obs/")):
        for m in re.finditer(r"\bHashMap\b", code):
            report(m.start(), "nondeterminism", "`HashMap` on a solver path — use `BTreeMap` or waive (lookup-only)")
        for m in re.finditer(r"\b(SystemTime|Instant)\b", code):
            report(m.start(), "nondeterminism", "`%s` outside bench/ or obs/ — wall-clock on a solver path" % m.group(1))

    # R4 fail-closed — data/ and util/json.rs
    if rel.startswith("data/") or rel == "util/json.rs":
        for m in re.finditer(r"\bpub\s+fn\s+([A-Za-z0-9_]+)", code):
            name = m.group(1).lower()
            if not any(d in name for d in DECODER_NAMES):
                continue
            j = m.end()
            while j < len(code) and code[j] not in "{;":
                j += 1
            sig = code[m.start():j]
            if "Result" not in sig:
                report(m.start(), "fail-closed", "decoder `pub fn %s` must return `Result`" % m.group(1))

    # Apply waivers
    kept = []
    for lineno, rule, msg in viol:
        if rule in file_waivers:
            continue
        if any(rule in rules and a <= lineno <= b for rules, a, b in waivers):
            continue
        kept.append((lineno, rule, msg))
    for lineno, msg in bad:
        kept.append((lineno, "bad-waiver", msg))
    kept.sort()
    return kept


def main():
    root = sys.argv[1] if len(sys.argv) > 1 else os.path.join(
        os.path.dirname(os.path.abspath(__file__)), "..", "..", "rust", "src")
    root = os.path.normpath(root)
    files = []
    for dirpath, _, names in os.walk(root):
        for nm in sorted(names):
            if nm.endswith(".rs"):
                files.append(os.path.join(dirpath, nm))
    files.sort()
    total = 0
    for path in files:
        rel = os.path.relpath(path, root).replace(os.sep, "/")
        with open(path, encoding="utf-8") as fh:
            src = fh.read()
        for lineno, rule, msg in lint_file(rel, src):
            print("%s:%d: [%s] %s" % (rel, lineno, rule, msg))
            total += 1
    if total:
        print("fica-lint (mirror): %d violation(s)" % total)
        return 1
    print("fica-lint (mirror): clean (%d files)" % len(files))
    return 0


if __name__ == "__main__":
    sys.exit(main())

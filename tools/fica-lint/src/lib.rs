//! `fica-lint`: a dependency-free lint pass enforcing the determinism
//! and safety contracts of the `faster-ica` solver core.
//!
//! The engine is a length-preserving source scanner (comments and
//! string contents blanked, newlines kept so offsets map to line
//! numbers), a `#[cfg(test)]`-item eraser, and four text rules:
//!
//! - **no-panic** — `.unwrap()` / `.expect()` / `panic!` / bare
//!   `assert!` (plus `unreachable!`, `todo!`, `unimplemented!`) are
//!   banned in non-test library code; typed [`IcaError`] paths or
//!   `debug_assert!` are the sanctioned alternatives.
//! - **float-accum** — raw `+=` / `.sum()` accumulation in `backend/`,
//!   `linalg/` and `data/stats.rs` must live inside the sanctioned
//!   fixed-order reduction helpers ([`SANCTIONED_FNS`]) so the bitwise
//!   determinism contract stays auditable in one place.
//! - **nondeterminism** — `HashMap`, `SystemTime` and `Instant` are
//!   banned outside `bench/` and `obs/` (iteration order / wall-clock
//!   on a solver path; the observability layer's whole job is reading
//!   the clock, and its output never feeds the numerics).
//! - **fail-closed** — decoder-shaped `pub fn`s in `data/` and
//!   `util/json.rs` must return `Result`.
//!
//! Violations are silenced by scoped waivers carrying a justification:
//! `// fica-lint: allow(rule, ...) — why this one is sound`, either
//! trailing (covers its own line) or standalone (covers the next
//! statement or item), or `allow-file(rule)` for a whole file. A waiver
//! without a justification, or naming an unknown rule, is itself a
//! violation (`bad-waiver`).
//!
//! `tools/fica-lint/mirror.py` is a toolchain-less Python mirror of
//! this engine (byte-for-byte the same semantics) for environments
//! without cargo; this crate is what CI runs.
//!
//! [`IcaError`]: https://docs.rs/faster-ica

use std::collections::BTreeSet;

/// The four enforceable rules, in report order.
pub const RULES: [&str; 4] = ["no-panic", "float-accum", "nondeterminism", "fail-closed"];

/// Functions whose bodies may accumulate floats freely: the fixed-order
/// lane fold and pairwise tree reduction (`backend/`), and the
/// `StreamingStats` moment accumulators (`data/stats.rs`). Keeping the
/// list tiny is the point — every float reduction order in the solver
/// core is pinned inside one of these.
pub const SANCTIONED_FNS: [&str; 7] =
    ["fold_lanes", "tree_reduce", "combine", "combine_vec", "absorb", "update", "partial"];

/// Substrings marking a `pub fn` as a decoder for the fail-closed rule.
pub const DECODER_NAMES: [&str; 7] =
    ["parse", "decode", "open", "read", "load", "from_bytes", "next_chunk"];

const PANIC_MACROS: [&str; 5] = ["panic", "assert", "unreachable", "todo", "unimplemented"];

/// One reported violation.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Violation {
    /// 1-based source line.
    pub line: usize,
    /// Rule name (one of [`RULES`] or `bad-waiver`).
    pub rule: &'static str,
    /// Human-readable message.
    pub msg: String,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

fn is_ascii_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn blank(out: &mut [char], a: usize, b: usize) {
    for slot in out.iter_mut().take(b.min(out.len())).skip(a) {
        if *slot != '\n' {
            *slot = ' ';
        }
    }
}

fn find_chars(hay: &[char], from: usize, needle: &[char]) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    (from..=hay.len() - needle.len()).find(|&i| &hay[i..i + needle.len()] == needle)
}

/// Blank comments and string/char-literal contents, preserving length
/// and newlines. Returns `(code, comments)` where each comment is
/// `(char_offset, text)`.
pub fn strip_source(src: &str) -> (Vec<char>, Vec<(usize, String)>) {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut out = s.clone();
    let mut comments = Vec::new();
    let mut i = 0;
    while i < n {
        let c = s[i];
        let nxt = if i + 1 < n { s[i + 1] } else { '\0' };
        if c == '/' && nxt == '/' {
            let mut j = i;
            while j < n && s[j] != '\n' {
                j += 1;
            }
            comments.push((i, s[i..j].iter().collect()));
            blank(&mut out, i, j);
            i = j;
        } else if c == '/' && nxt == '*' {
            let mut depth = 1;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if s[j] == '/' && j + 1 < n && s[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if s[j] == '*' && j + 1 < n && s[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            comments.push((i, s[i..j].iter().collect()));
            blank(&mut out, i, j);
            i = j;
        } else if c == '"' {
            let mut j = i + 1;
            while j < n {
                if s[j] == '\\' {
                    j += 2;
                } else if s[j] == '"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            blank(&mut out, i + 1, j.saturating_sub(1).max(i + 1));
            i = j;
        } else if (c == 'r' || c == 'b') && (i == 0 || !is_ident(s[i - 1])) {
            // Raw string r"..." / r#"..."# / byte string b"..." / br#"..."#.
            let mut j = i + 1;
            let mut raw = c == 'r';
            if c == 'b' && j < n && s[j] == 'r' {
                raw = true;
                j += 1;
            }
            let mut hashes = 0;
            while j < n && s[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if raw && j < n && s[j] == '"' {
                j += 1;
                let mut end: Vec<char> = vec!['"'];
                end.resize(1 + hashes, '#');
                let k = match find_chars(&s, j, &end) {
                    Some(k) => k + end.len(),
                    None => n,
                };
                blank(&mut out, i + 1, (k - end.len().min(k)).max(i + 1));
                i = k;
            } else if !raw && hashes == 0 && j < n && s[j] == '"' {
                // b"..." — same escape rules as a normal string.
                j += 1;
                while j < n {
                    if s[j] == '\\' {
                        j += 2;
                    } else if s[j] == '"' {
                        j += 1;
                        break;
                    } else {
                        j += 1;
                    }
                }
                blank(&mut out, i + 2, j.saturating_sub(1).max(i + 2));
                i = j;
            } else {
                i += 1;
            }
        } else if c == '\'' {
            // Char literal vs lifetime.
            if nxt == '\\' {
                let mut j = i + 2;
                while j < n && s[j] != '\'' {
                    j += 1;
                }
                j += 1;
                blank(&mut out, i + 1, j.saturating_sub(1).max(i + 1));
                i = j;
            } else if i + 2 < n && s[i + 2] == '\'' && nxt != '\'' {
                blank(&mut out, i + 1, i + 2);
                i += 3;
            } else {
                i += 1; // lifetime
            }
        } else {
            i += 1;
        }
    }
    (out, comments)
}

/// 1-based line number of a char offset.
pub fn line_of(code: &[char], off: usize) -> usize {
    code.iter().take(off.min(code.len())).filter(|&&c| c == '\n').count() + 1
}

/// `(start, end)` char offsets of a 1-based line (end excludes the newline).
fn line_bounds(code: &[char], lineno: usize) -> (usize, usize) {
    let mut start = 0;
    let mut line = 1;
    for (i, &c) in code.iter().enumerate() {
        if line == lineno && c == '\n' {
            return (start, i);
        }
        if c == '\n' {
            line += 1;
            start = i + 1;
        }
    }
    (start, code.len())
}

/// Index just past the `}` matching the `{` at `open_idx` (or `len`).
fn match_brace(code: &[char], open_idx: usize) -> usize {
    let mut depth = 0i64;
    for (j, &c) in code.iter().enumerate().skip(open_idx) {
        if c == '{' {
            depth += 1;
        } else if c == '}' {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
    }
    code.len()
}

/// Blank every item annotated `#[cfg(test)]` (to its closing brace or `;`).
pub fn blank_cfg_test(code: &mut [char]) {
    let attr: Vec<char> = "#[cfg(test)]".chars().collect();
    let mut starts = Vec::new();
    let mut from = 0;
    while let Some(i) = find_chars(code, from, &attr) {
        starts.push(i);
        from = i + attr.len();
    }
    for start in starts {
        let mut j = start + attr.len();
        while j < code.len() && code[j] != '{' && code[j] != ';' {
            j += 1;
        }
        let end = if j < code.len() && code[j] == '{' { match_brace(code, j) } else { j + 1 };
        let upper = end.min(code.len());
        blank(code, start, upper);
    }
}

/// A scoped waiver: which rules it silences, over which 1-based lines.
#[derive(Debug, Clone)]
pub struct Waiver {
    rules: BTreeSet<String>,
    line_start: usize,
    line_end: usize,
}

/// Parsed waivers for one file.
#[derive(Debug, Default)]
pub struct Waivers {
    scoped: Vec<Waiver>,
    file_wide: BTreeSet<String>,
    /// Malformed waivers: `(line, message)`.
    bad: Vec<(usize, String)>,
}

fn parse_one_waiver(text: &str) -> Option<(bool, String, String)> {
    // `fica-lint:` then ws, `allow` or `allow-file`, `(` rules `)`, rest.
    let at = text.find("fica-lint:")?;
    let rest = &text[at + "fica-lint:".len()..];
    let rest = rest.trim_start();
    let rest = rest.strip_prefix("allow")?;
    let (file_wide, rest) = match rest.strip_prefix("-file") {
        Some(r) => (true, r),
        None => (false, rest),
    };
    let rest = rest.strip_prefix('(')?;
    let close = rest.find(')')?;
    let rules_raw = rest[..close].to_string();
    let mut just = rest[close + 1..].trim().to_string();
    for dash in ["—", "–", "--", "-"] {
        if let Some(stripped) = just.strip_prefix(dash) {
            just = stripped.trim_start().to_string();
            break;
        }
    }
    Some((file_wide, rules_raw, just))
}

/// Extract waivers from the comment list. `code` is the stripped source
/// (used for line numbers and statement-scope resolution).
pub fn parse_waivers(code: &[char], comments: &[(usize, String)]) -> Waivers {
    let mut w = Waivers::default();
    for (off, text) in comments {
        let Some((file_wide, rules_raw, just)) = parse_one_waiver(text) else {
            continue;
        };
        let lineno = line_of(code, *off);
        let rules: BTreeSet<String> = rules_raw
            .split(',')
            .map(|r| r.trim().to_string())
            .filter(|r| !r.is_empty())
            .collect();
        if rules.is_empty() || !rules.iter().all(|r| RULES.contains(&r.as_str())) {
            w.bad.push((lineno, format!("waiver names unknown rule(s): {}", rules_raw.trim())));
            continue;
        }
        if just.is_empty() {
            w.bad.push((lineno, "waiver without justification".to_string()));
            continue;
        }
        if file_wide {
            w.file_wide.extend(rules);
            continue;
        }
        let (ls, le) = line_bounds(code, lineno);
        let trailing = code[ls..(*off).min(code.len())].iter().any(|c| !c.is_whitespace());
        if trailing {
            // Trailing waiver: covers its own line.
            w.scoped.push(Waiver { rules, line_start: lineno, line_end: lineno });
            continue;
        }
        // Standalone: covers the next statement-or-item. Scan from the
        // first code char after the waiver line; the scope ends at a `;`
        // at depth <= 0, or at the `}` that brings depth to <= 0 — the
        // `<= 0` (not `== 0`) matters when the waived code is a match
        // arm or tail expression, where the first `}` seen closes the
        // *enclosing* block.
        let mut j = le + 1;
        while j < code.len() && code[j].is_whitespace() {
            j += 1;
        }
        let mut depth = 0i64;
        let mut end = code.len();
        let mut k = j;
        while k < code.len() {
            let ch = code[k];
            if ch == '{' {
                depth += 1;
            } else if ch == '}' {
                depth -= 1;
                if depth <= 0 {
                    end = k + 1;
                    break;
                }
            } else if ch == ';' && depth <= 0 {
                end = k + 1;
                break;
            }
            k += 1;
        }
        w.scoped.push(Waiver {
            rules,
            line_start: line_of(code, j),
            line_end: line_of(code, end.min(code.len().saturating_sub(1))),
        });
    }
    w
}

/// `(name, start, end)` char ranges of every `fn name ... { ... }`.
fn fn_ranges(code: &[char]) -> Vec<(String, usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0;
    let n = code.len();
    while i < n {
        // Word-boundary `fn` followed by whitespace and an identifier.
        if code[i] == 'f'
            && i + 1 < n
            && code[i + 1] == 'n'
            && (i == 0 || !is_ascii_ident(code[i - 1]))
            && (i + 2 >= n || !is_ascii_ident(code[i + 2]))
        {
            let mut j = i + 2;
            let ws_start = j;
            while j < n && code[j].is_whitespace() {
                j += 1;
            }
            if j > ws_start && j < n && is_ascii_ident(code[j]) {
                let name_start = j;
                while j < n && is_ascii_ident(code[j]) {
                    j += 1;
                }
                let name: String = code[name_start..j].iter().collect();
                while j < n && code[j] != '{' && code[j] != ';' {
                    j += 1;
                }
                if j < n && code[j] == '{' {
                    out.push((name, i, match_brace(code, j)));
                }
            }
        }
        i += 1;
    }
    out
}

/// Name of the innermost function whose body contains `off`.
fn enclosing_fn<'a>(ranges: &'a [(String, usize, usize)], off: usize) -> Option<&'a str> {
    ranges
        .iter()
        .filter(|(_, a, b)| *a <= off && off < *b)
        .max_by_key(|(_, a, _)| *a)
        .map(|(name, _, _)| name.as_str())
}

/// Whether `s` is a plain integer literal (optionally suffixed), e.g.
/// `1`, `2_000`, `1usize` — the float-accum exemption for counters.
fn is_int_literal(s: &str) -> bool {
    let body = ["u8", "u16", "u32", "u64", "usize", "i8", "i16", "i32", "i64", "isize"]
        .iter()
        .find_map(|suf| s.strip_suffix(suf))
        .unwrap_or(s);
    let mut chars = body.chars();
    matches!(chars.next(), Some(c) if c.is_ascii_digit())
        && chars.all(|c| c.is_ascii_digit() || c == '_')
}

/// Maximal ASCII identifier starting at `i` (empty if none).
fn ident_at(code: &[char], i: usize) -> (usize, String) {
    let mut j = i;
    while j < code.len() && is_ascii_ident(code[j]) {
        j += 1;
    }
    (j, code[i..j].iter().collect())
}

fn skip_ws(code: &[char], mut i: usize) -> usize {
    while i < code.len() && code[i].is_whitespace() {
        i += 1;
    }
    i
}

struct RuleSink {
    viol: Vec<Violation>,
}

impl RuleSink {
    fn report(&mut self, code: &[char], off: usize, rule: &'static str, msg: String) {
        self.viol.push(Violation { line: line_of(code, off), rule, msg });
    }
}

fn rule_no_panic(code: &[char], sink: &mut RuleSink) {
    let n = code.len();
    let mut i = 0;
    while i < n {
        if code[i] == '.' {
            let j = skip_ws(code, i + 1);
            let (k, name) = ident_at(code, j);
            if (name == "unwrap" || name == "expect") && code.get(skip_ws(code, k)) == Some(&'(') {
                sink.report(
                    code,
                    i,
                    "no-panic",
                    format!("`.{name}()` in library code — use a typed `IcaError` path"),
                );
            }
        }
        if is_ascii_ident(code[i]) && (i == 0 || !is_ascii_ident(code[i - 1])) {
            let (j, name) = ident_at(code, i);
            if PANIC_MACROS.contains(&name.as_str()) && code.get(j) == Some(&'!') {
                let k = skip_ws(code, j + 1);
                if matches!(code.get(k), Some('(') | Some('[') | Some('{')) {
                    sink.report(
                        code,
                        i,
                        "no-panic",
                        format!("`{name}!` in library code — use `debug_assert!` or a typed error"),
                    );
                }
            }
            i = j;
            continue;
        }
        i += 1;
    }
}

fn rule_float_accum(code: &[char], ranges: &[(String, usize, usize)], sink: &mut RuleSink) {
    let n = code.len();
    let mut i = 0;
    while i + 1 < n {
        if code[i] == '+' && code[i + 1] == '=' {
            let (_, le) = line_bounds(code, line_of(code, i));
            let rhs: String = code[(i + 2).min(le)..le].iter().collect();
            let rhs = rhs.trim().trim_end_matches(';').trim();
            let sanctioned =
                enclosing_fn(ranges, i).is_some_and(|f| SANCTIONED_FNS.contains(&f));
            if !is_int_literal(rhs) && !sanctioned {
                sink.report(
                    code,
                    i,
                    "float-accum",
                    "raw `+=` accumulation outside sanctioned reduction helpers".to_string(),
                );
            }
            i += 2;
            continue;
        }
        if code[i] == '.' {
            let j = skip_ws(code, i + 1);
            let (mut k, name) = ident_at(code, j);
            if name == "sum" {
                k = skip_ws(code, k);
                // Optional turbofish `::<T>`.
                if code.get(k) == Some(&':') && code.get(k + 1) == Some(&':') {
                    let t = skip_ws(code, k + 2);
                    if code.get(t) == Some(&'<') {
                        if let Some(gt) = (t..n).find(|&p| code[p] == '>') {
                            k = skip_ws(code, gt + 1);
                        }
                    }
                }
                if code.get(k) == Some(&'(') {
                    let sanctioned =
                        enclosing_fn(ranges, i).is_some_and(|f| SANCTIONED_FNS.contains(&f));
                    if !sanctioned {
                        sink.report(
                            code,
                            i,
                            "float-accum",
                            "`.sum()` reduction outside sanctioned helpers — order must be pinned"
                                .to_string(),
                        );
                    }
                }
            }
        }
        i += 1;
    }
}

fn rule_nondeterminism(code: &[char], sink: &mut RuleSink) {
    let mut i = 0;
    while i < code.len() {
        if is_ascii_ident(code[i]) && (i == 0 || !is_ascii_ident(code[i - 1])) {
            let (j, name) = ident_at(code, i);
            match name.as_str() {
                "HashMap" => sink.report(
                    code,
                    i,
                    "nondeterminism",
                    "`HashMap` on a solver path — use `BTreeMap` or waive (lookup-only)"
                        .to_string(),
                ),
                "SystemTime" | "Instant" => sink.report(
                    code,
                    i,
                    "nondeterminism",
                    format!("`{name}` outside bench/ or obs/ — wall-clock on a solver path"),
                ),
                _ => {}
            }
            i = j;
            continue;
        }
        i += 1;
    }
}

fn rule_fail_closed(code: &[char], sink: &mut RuleSink) {
    let n = code.len();
    let mut i = 0;
    while i < n {
        if code[i] == 'p'
            && (i == 0 || !is_ascii_ident(code[i - 1]))
            && code[i..].starts_with(&['p', 'u', 'b'])
            && code.get(i + 3).is_some_and(|c| c.is_whitespace())
        {
            let j = skip_ws(code, i + 3);
            if code[j..].starts_with(&['f', 'n'])
                && code.get(j + 2).is_some_and(|c| c.is_whitespace())
            {
                let k = skip_ws(code, j + 2);
                let (mut e, name) = ident_at(code, k);
                if !name.is_empty() {
                    let lower = name.to_lowercase();
                    if DECODER_NAMES.iter().any(|d| lower.contains(d)) {
                        while e < n && code[e] != '{' && code[e] != ';' {
                            e += 1;
                        }
                        let sig: String = code[i..e].iter().collect();
                        if !sig.contains("Result") {
                            sink.report(
                                code,
                                i,
                                "fail-closed",
                                format!("decoder `pub fn {name}` must return `Result`"),
                            );
                        }
                    }
                }
            }
        }
        i += 1;
    }
}

/// Lint one file. `rel` is the path relative to the lint root, with `/`
/// separators (rule applicability is path-scoped).
pub fn lint_file(rel: &str, src: &str) -> Vec<Violation> {
    let (code0, comments) = strip_source(src);
    let waivers = parse_waivers(&code0, &comments);
    let mut code = code0;
    blank_cfg_test(&mut code);
    let ranges = fn_ranges(&code);
    let mut sink = RuleSink { viol: Vec::new() };

    rule_no_panic(&code, &mut sink);
    if rel.starts_with("backend/") || rel.starts_with("linalg/") || rel == "data/stats.rs" {
        rule_float_accum(&code, &ranges, &mut sink);
    }
    if !(rel.starts_with("bench/") || rel.starts_with("obs/")) {
        rule_nondeterminism(&code, &mut sink);
    }
    if rel.starts_with("data/") || rel == "util/json.rs" {
        rule_fail_closed(&code, &mut sink);
    }

    let mut kept: Vec<Violation> = sink
        .viol
        .into_iter()
        .filter(|v| !waivers.file_wide.contains(v.rule))
        .filter(|v| {
            !waivers.scoped.iter().any(|w| {
                w.rules.contains(v.rule) && w.line_start <= v.line && v.line <= w.line_end
            })
        })
        .collect();
    for (line, msg) in waivers.bad {
        kept.push(Violation { line, rule: "bad-waiver", msg });
    }
    kept.sort();
    kept
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let src = "let s = \"panic!(\"; // .unwrap()\nlet c = '\\'';";
        let (code, comments) = strip_source(src);
        let text: String = code.iter().collect();
        assert!(!text.contains("panic"));
        assert!(!text.contains("unwrap"));
        assert_eq!(comments.len(), 1);
        assert_eq!(text.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn raw_strings_preserve_length() {
        let src = "let s = r#\"has .unwrap() inside\"#; x.unwrap();";
        let (code, _) = strip_source(src);
        assert_eq!(code.len(), src.chars().count());
        let text: String = code.iter().collect();
        assert_eq!(text.matches("unwrap").count(), 1);
    }

    #[test]
    fn int_literals() {
        assert!(is_int_literal("1"));
        assert!(is_int_literal("2_000"));
        assert!(is_int_literal("7usize"));
        assert!(!is_int_literal("x"));
        assert!(!is_int_literal("1.0"));
        assert!(!is_int_literal(""));
    }

    #[test]
    fn lifetime_is_not_a_char_literal() {
        let src = "fn f<'a>(x: &'a str) { x.expect(\"e\"); }";
        let v = lint_file("x.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "no-panic");
    }

    #[test]
    fn assert_eq_is_not_bare_assert() {
        let v = lint_file("x.rs", "fn f() { assert_eq!(1, 1); debug_assert!(true); }");
        assert!(v.is_empty(), "{v:?}");
    }
}

//! `fica-lint` / **fica-audit**: a dependency-free static analysis pass
//! enforcing the determinism, safety and cross-file consistency
//! contracts of the `faster-ica` workspace.
//!
//! The engine has two stages:
//!
//! 1. **Token stage** (this module): a length-preserving source scanner
//!    (comments and string contents blanked, newlines kept so offsets
//!    map to line numbers), a `#[cfg(test)]`-item eraser, and the
//!    per-file rules:
//!    - **no-panic** — `.unwrap()` / `.expect()` / `panic!` / bare
//!      `assert!` (plus `unreachable!`, `todo!`, `unimplemented!`) are
//!      banned in non-test library code; typed [`IcaError`] paths or
//!      `debug_assert!` are the sanctioned alternatives.
//!    - **float-accum** — raw `+=` / `.sum()` accumulation in
//!      `backend/`, `linalg/` and `data/stats.rs` must live inside the
//!      sanctioned fixed-order reduction helpers ([`SANCTIONED_FNS`]).
//!    - **nondeterminism** — `HashMap`, `SystemTime` and `Instant` are
//!      banned outside `bench/` and `obs/`.
//!    - **fail-closed** — decoder-shaped `pub fn`s in `data/` and
//!      `util/json.rs` must return `Result`.
//!    - **unchecked-arith** — raw `*` / `+` on size-typed operands in
//!      the decoder paths (`data/` minus `data/stats.rs`, plus
//!      `util/json.rs` and the wire decoders in `daemon/`) must use
//!      `checked_*` / `saturating_*` instead.
//!    - **lock-hygiene** — in `backend/pool.rs`, `coordinator/` and
//!      `daemon/` code: every file that acquires locks declares
//!      a canonical acquisition order in a `lock-order` header comment;
//!      no channel call while a guard is live, no out-of-order nested
//!      acquisition.
//!
//! 2. **Item-graph stage** ([`audit`], built on [`scan_items`]): the
//!    whole workspace is loaded into one model and the cross-file rules
//!    run — **schema-drift** (code / docs / fixture `fica.<family>/vN`
//!    tags must agree), **contract-coverage** (every ARCHITECTURE.md
//!    equivalence-contract row resolves to live test fns), and
//!    **stale-waiver** (a waiver that no longer suppresses anything is
//!    itself a violation).
//!
//! Violations are silenced by scoped waivers carrying a justification —
//! an `allow` directive naming the waived rules in parentheses, then a
//! dash, then why the site is sound (see `docs/LINT_RULES.md` for the
//! grammar) — either trailing (covers its own line), standalone (covers
//! the next statement or item), or `allow-file` for a whole file. A
//! waiver without a justification, or naming an unknown or unwaivable
//! rule, is itself a violation (`bad-waiver`); a waiver that suppresses
//! nothing is reported by `stale-waiver`.
//!
//! `tools/fica-lint/mirror.py` is a toolchain-less Python mirror of
//! this engine (byte-for-byte the same report, proven by the CI parity
//! gate); this crate is what the rust CI job runs.
//!
//! [`IcaError`]: https://docs.rs/faster-ica

pub mod audit;
mod items;

pub use items::{scan_calls, scan_items, Item, ItemKind};

/// The nine enforceable rules, in report order. `bad-waiver` is the
/// implicit tenth: malformed waivers are always reported.
pub const RULES: [&str; 9] = [
    "no-panic",
    "float-accum",
    "nondeterminism",
    "fail-closed",
    "unchecked-arith",
    "lock-hygiene",
    "schema-drift",
    "contract-coverage",
    "stale-waiver",
];

/// The rules a waiver may name. The cross-file rules (`schema-drift`,
/// `contract-coverage`) and the meta rule (`stale-waiver`) cannot be
/// waived — drift is fixed at the source, not silenced.
pub const WAIVABLE: [&str; 6] = [
    "no-panic",
    "float-accum",
    "nondeterminism",
    "fail-closed",
    "unchecked-arith",
    "lock-hygiene",
];

/// Functions whose bodies may accumulate floats freely: the fixed-order
/// lane fold and pairwise tree reduction (`backend/`), and the
/// `StreamingStats` moment accumulators (`data/stats.rs`). Keeping the
/// list tiny is the point — every float reduction order in the solver
/// core is pinned inside one of these.
pub const SANCTIONED_FNS: [&str; 7] =
    ["fold_lanes", "tree_reduce", "combine", "combine_vec", "absorb", "update", "partial"];

/// Substrings marking a `pub fn` as a decoder for the fail-closed rule.
pub const DECODER_NAMES: [&str; 7] =
    ["parse", "decode", "open", "read", "load", "from_bytes", "next_chunk"];

/// Identifier heads/tails marking an operand as size-typed for the
/// unchecked-arith rule: `len`, `self.pos`, `byte_off`, `n_cols`, …
pub const SIZE_MARKERS: [&str; 16] = [
    "bytes", "cap", "chunk", "cols", "count", "idx", "len", "n", "nbytes", "off", "offset", "pos",
    "rows", "size", "stride", "written",
];

/// Channel methods that must not be called while a lock guard is live.
pub const CHANNEL_METHODS: [&str; 6] =
    ["recv", "recv_timeout", "send", "send_timeout", "try_recv", "try_send"];

const PANIC_MACROS: [&str; 5] = ["panic", "assert", "unreachable", "todo", "unimplemented"];

/// One reported violation. The derived ordering (path, line, span,
/// rule, msg, waived) is the report order, identical in `mirror.py`.
#[derive(Debug, Clone, PartialEq, Eq, PartialOrd, Ord)]
pub struct Violation {
    /// Report path (workspace-relative in audit mode).
    pub path: String,
    /// 1-based source line.
    pub line: usize,
    /// Char-offset span `[start, end)` within the file.
    pub span: (usize, usize),
    /// Rule name (one of [`RULES`] or `bad-waiver`).
    pub rule: &'static str,
    /// Human-readable message.
    pub msg: String,
    /// Whether a waiver silenced this violation (kept in `--json`
    /// output; text output prints unwaived violations only).
    pub waived: bool,
}

fn is_ident(c: char) -> bool {
    c.is_alphanumeric() || c == '_'
}

pub(crate) fn is_ascii_ident(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn blank(out: &mut [char], a: usize, b: usize) {
    for slot in out.iter_mut().take(b.min(out.len())).skip(a) {
        if *slot != '\n' {
            *slot = ' ';
        }
    }
}

fn find_chars(hay: &[char], from: usize, needle: &[char]) -> Option<usize> {
    if needle.is_empty() || hay.len() < needle.len() {
        return None;
    }
    (from..=hay.len() - needle.len()).find(|&i| &hay[i..i + needle.len()] == needle)
}

/// [`strip_source`] output: blanked code plus the comment and
/// string-literal inventory (char offsets into the blanked buffer).
#[derive(Debug, Default)]
pub struct Stripped {
    /// Source with comment and string/char contents blanked,
    /// length-preserving (newlines kept).
    pub code: Vec<char>,
    /// `(char_offset, text)` of every comment.
    pub comments: Vec<(usize, String)>,
    /// `(content_char_offset, content)` of every string literal
    /// (normal and raw; byte strings are skipped — they hold bytes,
    /// not schema tags).
    pub strings: Vec<(usize, String)>,
}

/// Blank comments and string/char-literal contents, preserving length
/// and newlines, collecting the comment and string inventories.
pub fn strip_source(src: &str) -> Stripped {
    let s: Vec<char> = src.chars().collect();
    let n = s.len();
    let mut out = s.clone();
    let mut comments = Vec::new();
    let mut strings = Vec::new();
    let mut i = 0;
    while i < n {
        let c = s[i];
        let nxt = if i + 1 < n { s[i + 1] } else { '\0' };
        if c == '/' && nxt == '/' {
            let mut j = i;
            while j < n && s[j] != '\n' {
                j += 1;
            }
            comments.push((i, s[i..j].iter().collect()));
            blank(&mut out, i, j);
            i = j;
        } else if c == '/' && nxt == '*' {
            let mut depth = 1;
            let mut j = i + 2;
            while j < n && depth > 0 {
                if s[j] == '/' && j + 1 < n && s[j + 1] == '*' {
                    depth += 1;
                    j += 2;
                } else if s[j] == '*' && j + 1 < n && s[j + 1] == '/' {
                    depth -= 1;
                    j += 2;
                } else {
                    j += 1;
                }
            }
            comments.push((i, s[i..j].iter().collect()));
            blank(&mut out, i, j);
            i = j;
        } else if c == '"' {
            let mut j = i + 1;
            while j < n {
                if s[j] == '\\' {
                    j += 2;
                } else if s[j] == '"' {
                    j += 1;
                    break;
                } else {
                    j += 1;
                }
            }
            let content_end = j.saturating_sub(1).max(i + 1);
            strings.push((i + 1, s[i + 1..content_end.min(n)].iter().collect()));
            blank(&mut out, i + 1, content_end);
            i = j;
        } else if (c == 'r' || c == 'b') && (i == 0 || !is_ident(s[i - 1])) {
            // Raw string r"..." / r#"..."# / byte string b"..." / br#"..."#.
            let mut j = i + 1;
            let mut raw = c == 'r';
            if c == 'b' && j < n && s[j] == 'r' {
                raw = true;
                j += 1;
            }
            let mut hashes = 0;
            while j < n && s[j] == '#' {
                hashes += 1;
                j += 1;
            }
            if raw && j < n && s[j] == '"' {
                j += 1;
                let mut end: Vec<char> = vec!['"'];
                end.resize(1 + hashes, '#');
                let k = match find_chars(&s, j, &end) {
                    Some(k) => k + end.len(),
                    None => n,
                };
                let content_end = (k - end.len().min(k)).max(i + 1);
                if c == 'r' {
                    strings.push((j, s[j..content_end.min(n)].iter().collect()));
                }
                blank(&mut out, i + 1, content_end);
                i = k;
            } else if !raw && hashes == 0 && j < n && s[j] == '"' {
                // b"..." — same escape rules as a normal string.
                j += 1;
                while j < n {
                    if s[j] == '\\' {
                        j += 2;
                    } else if s[j] == '"' {
                        j += 1;
                        break;
                    } else {
                        j += 1;
                    }
                }
                blank(&mut out, i + 2, j.saturating_sub(1).max(i + 2));
                i = j;
            } else {
                i += 1;
            }
        } else if c == '\'' {
            // Char literal vs lifetime.
            if nxt == '\\' {
                let mut j = i + 2;
                while j < n && s[j] != '\'' {
                    j += 1;
                }
                j += 1;
                blank(&mut out, i + 1, j.saturating_sub(1).max(i + 1));
                i = j;
            } else if i + 2 < n && s[i + 2] == '\'' && nxt != '\'' {
                blank(&mut out, i + 1, i + 2);
                i += 3;
            } else {
                i += 1; // lifetime
            }
        } else {
            i += 1;
        }
    }
    Stripped { code: out, comments, strings }
}

/// 1-based line number of a char offset.
pub fn line_of(code: &[char], off: usize) -> usize {
    code.iter().take(off.min(code.len())).filter(|&&c| c == '\n').count() + 1
}

/// `(start, end)` char offsets of a 1-based line (end excludes the newline).
fn line_bounds(code: &[char], lineno: usize) -> (usize, usize) {
    let mut start = 0;
    let mut line = 1;
    for (i, &c) in code.iter().enumerate() {
        if line == lineno && c == '\n' {
            return (start, i);
        }
        if c == '\n' {
            line += 1;
            start = i + 1;
        }
    }
    (start, code.len())
}

/// Index just past the `}` matching the `{` at `open_idx` (or `len`).
pub(crate) fn match_brace(code: &[char], open_idx: usize) -> usize {
    let mut depth = 0i64;
    for (j, &c) in code.iter().enumerate().skip(open_idx) {
        if c == '{' {
            depth += 1;
        } else if c == '}' {
            depth -= 1;
            if depth == 0 {
                return j + 1;
            }
        }
    }
    code.len()
}

/// Blank every item annotated `#[cfg(test)]` (to its closing brace or
/// `;`), returning the erased `(start, end)` regions.
pub fn blank_cfg_test(code: &mut [char]) -> Vec<(usize, usize)> {
    let attr: Vec<char> = "#[cfg(test)]".chars().collect();
    let mut starts = Vec::new();
    let mut from = 0;
    while let Some(i) = find_chars(code, from, &attr) {
        starts.push(i);
        from = i + attr.len();
    }
    let mut regions = Vec::new();
    for start in starts {
        let mut j = start + attr.len();
        while j < code.len() && code[j] != '{' && code[j] != ';' {
            j += 1;
        }
        let end = if j < code.len() && code[j] == '{' { match_brace(code, j) } else { j + 1 };
        let upper = end.min(code.len());
        blank(code, start, upper);
        regions.push((start, upper));
    }
    regions
}

/// A scoped or file-wide waiver: which rules it silences, over which
/// 1-based lines, plus per-rule usage tracking for `stale-waiver`.
#[derive(Debug, Clone)]
pub struct Waiver {
    /// Waived rules, sorted and deduped.
    rules: Vec<String>,
    line_start: usize,
    line_end: usize,
    /// The waiver comment's own line and char span (where staleness is
    /// reported).
    line: usize,
    span: (usize, usize),
    file_wide: bool,
    /// Parallel to `rules`: did this waiver silence at least one
    /// violation of that rule?
    used: Vec<bool>,
}

/// A `lock-order` declaration: the canonical acquisition order for the
/// lock-hygiene rule.
#[derive(Debug, Clone)]
pub struct LockOrder {
    /// Declared lock names, in acquisition order.
    pub names: Vec<String>,
    /// Comment line and span (where duplicates are reported).
    pub line: usize,
    pub span: (usize, usize),
}

/// Parsed waivers and declarations for one file.
#[derive(Debug, Default)]
pub struct Waivers {
    scoped: Vec<Waiver>,
    file_wide: Vec<Waiver>,
    /// `lock-order` declarations, in source order.
    pub lock_orders: Vec<LockOrder>,
    /// Malformed waivers: `(line, span, message)`.
    bad: Vec<(usize, (usize, usize), String)>,
}

enum Directive {
    Allow { file_wide: bool, rules_raw: String, just: String },
    DeclLockOrder { names_raw: String },
}

fn parse_directive(text: &str) -> Option<Directive> {
    // `fica-lint:` then ws, then an `allow` / `allow-file` waiver with
    // its parenthesized rule list and dash-separated justification, or
    // a `lock-order` declaration with its parenthesized lock list.
    let at = text.find("fica-lint:")?;
    let rest = &text[at + "fica-lint:".len()..];
    let rest = rest.trim_start();
    if let Some(rest) = rest.strip_prefix("lock-order") {
        let rest = rest.strip_prefix('(')?;
        let close = rest.find(')')?;
        return Some(Directive::DeclLockOrder { names_raw: rest[..close].to_string() });
    }
    let rest = rest.strip_prefix("allow")?;
    let (file_wide, rest) = match rest.strip_prefix("-file") {
        Some(r) => (true, r),
        None => (false, rest),
    };
    let rest = rest.strip_prefix('(')?;
    let close = rest.find(')')?;
    let rules_raw = rest[..close].to_string();
    let mut just = rest[close + 1..].trim().to_string();
    for dash in ["—", "–", "--", "-"] {
        if let Some(stripped) = just.strip_prefix(dash) {
            just = stripped.trim_start().to_string();
            break;
        }
    }
    Some(Directive::Allow { file_wide, rules_raw, just })
}

/// Extract waivers and `lock-order` declarations from the comment list.
/// `code` is the stripped source (used for line numbers and
/// statement-scope resolution).
pub fn scan_waivers(code: &[char], comments: &[(usize, String)]) -> Waivers {
    let mut w = Waivers::default();
    for (off, text) in comments {
        let lineno = line_of(code, *off);
        let span = (*off, *off + text.chars().count());
        match parse_directive(text) {
            None => continue,
            Some(Directive::DeclLockOrder { names_raw }) => {
                let names: Vec<String> = names_raw
                    .split(',')
                    .map(|r| r.trim().to_string())
                    .filter(|r| !r.is_empty())
                    .collect();
                if names.is_empty() {
                    w.bad.push((lineno, span, "lock-order declaration names no locks".to_string()));
                } else {
                    w.lock_orders.push(LockOrder { names, line: lineno, span });
                }
            }
            Some(Directive::Allow { file_wide, rules_raw, just }) => {
                let mut rules: Vec<String> = rules_raw
                    .split(',')
                    .map(|r| r.trim().to_string())
                    .filter(|r| !r.is_empty())
                    .collect();
                rules.sort();
                rules.dedup();
                if rules.is_empty() || !rules.iter().all(|r| WAIVABLE.contains(&r.as_str())) {
                    w.bad.push((
                        lineno,
                        span,
                        format!(
                            "waiver names unknown or unwaivable rule(s): {}",
                            rules_raw.trim()
                        ),
                    ));
                    continue;
                }
                if just.is_empty() {
                    w.bad.push((lineno, span, "waiver without justification".to_string()));
                    continue;
                }
                let used = vec![false; rules.len()];
                if file_wide {
                    w.file_wide.push(Waiver {
                        rules,
                        line_start: 0,
                        line_end: usize::MAX,
                        line: lineno,
                        span,
                        file_wide: true,
                        used,
                    });
                    continue;
                }
                let (ls, le) = line_bounds(code, lineno);
                let trailing =
                    code[ls..(*off).min(code.len())].iter().any(|c| !c.is_whitespace());
                if trailing {
                    // Trailing waiver: covers its own line.
                    w.scoped.push(Waiver {
                        rules,
                        line_start: lineno,
                        line_end: lineno,
                        line: lineno,
                        span,
                        file_wide: false,
                        used,
                    });
                    continue;
                }
                // Standalone: covers the next statement-or-item. Scan from
                // the first code char after the waiver line; the scope ends
                // at a `;` at depth <= 0, or at the `}` that brings depth to
                // <= 0 — the `<= 0` (not `== 0`) matters when the waived
                // code is a match arm or tail expression, where the first
                // `}` seen closes the *enclosing* block.
                let mut j = le + 1;
                while j < code.len() && code[j].is_whitespace() {
                    j += 1;
                }
                let mut depth = 0i64;
                let mut end = code.len();
                let mut k = j;
                while k < code.len() {
                    let ch = code[k];
                    if ch == '{' {
                        depth += 1;
                    } else if ch == '}' {
                        depth -= 1;
                        if depth <= 0 {
                            end = k + 1;
                            break;
                        }
                    } else if ch == ';' && depth <= 0 {
                        end = k + 1;
                        break;
                    }
                    k += 1;
                }
                w.scoped.push(Waiver {
                    rules,
                    line_start: line_of(code, j),
                    line_end: line_of(code, end.min(code.len().saturating_sub(1))),
                    line: lineno,
                    span,
                    file_wide: false,
                    used,
                });
            }
        }
    }
    w
}

/// `(name, start, end)` char ranges of every `fn name ... { ... }`.
fn fn_ranges(code: &[char]) -> Vec<(String, usize, usize)> {
    let mut out = Vec::new();
    let mut i = 0;
    let n = code.len();
    while i < n {
        // Word-boundary `fn` followed by whitespace and an identifier.
        if code[i] == 'f'
            && i + 1 < n
            && code[i + 1] == 'n'
            && (i == 0 || !is_ascii_ident(code[i - 1]))
            && (i + 2 >= n || !is_ascii_ident(code[i + 2]))
        {
            let mut j = i + 2;
            let ws_start = j;
            while j < n && code[j].is_whitespace() {
                j += 1;
            }
            if j > ws_start && j < n && is_ascii_ident(code[j]) {
                let name_start = j;
                while j < n && is_ascii_ident(code[j]) {
                    j += 1;
                }
                let name: String = code[name_start..j].iter().collect();
                while j < n && code[j] != '{' && code[j] != ';' {
                    j += 1;
                }
                if j < n && code[j] == '{' {
                    out.push((name, i, match_brace(code, j)));
                }
            }
        }
        i += 1;
    }
    out
}

/// Name of the innermost function whose body contains `off`.
fn enclosing_fn<'a>(ranges: &'a [(String, usize, usize)], off: usize) -> Option<&'a str> {
    ranges
        .iter()
        .filter(|(_, a, b)| *a <= off && off < *b)
        .max_by_key(|(_, a, _)| *a)
        .map(|(name, _, _)| name.as_str())
}

/// Whether `s` is a plain integer literal (optionally suffixed), e.g.
/// `1`, `2_000`, `1usize` — the float-accum exemption for counters.
fn is_int_literal(s: &str) -> bool {
    let body = ["u8", "u16", "u32", "u64", "usize", "i8", "i16", "i32", "i64", "isize"]
        .iter()
        .find_map(|suf| s.strip_suffix(suf))
        .unwrap_or(s);
    let mut chars = body.chars();
    matches!(chars.next(), Some(c) if c.is_ascii_digit())
        && chars.all(|c| c.is_ascii_digit() || c == '_')
}

/// Maximal ASCII identifier starting at `i` (empty if none).
pub(crate) fn ident_at(code: &[char], i: usize) -> (usize, String) {
    let mut j = i;
    while j < code.len() && is_ascii_ident(code[j]) {
        j += 1;
    }
    (j, code[i..j].iter().collect())
}

pub(crate) fn skip_ws(code: &[char], mut i: usize) -> usize {
    while i < code.len() && code[i].is_whitespace() {
        i += 1;
    }
    i
}

struct RuleSink {
    viol: Vec<Violation>,
}

impl RuleSink {
    fn report(
        &mut self,
        code: &[char],
        start: usize,
        end: usize,
        rule: &'static str,
        msg: String,
    ) {
        self.viol.push(Violation {
            path: String::new(),
            line: line_of(code, start),
            span: (start, end),
            rule,
            msg,
            waived: false,
        });
    }
}

fn rule_no_panic(code: &[char], sink: &mut RuleSink) {
    let n = code.len();
    let mut i = 0;
    while i < n {
        if code[i] == '.' {
            let j = skip_ws(code, i + 1);
            let (k, name) = ident_at(code, j);
            if (name == "unwrap" || name == "expect") && code.get(skip_ws(code, k)) == Some(&'(') {
                sink.report(
                    code,
                    i,
                    k,
                    "no-panic",
                    format!("`.{name}()` in library code — use a typed `IcaError` path"),
                );
            }
        }
        if is_ascii_ident(code[i]) && (i == 0 || !is_ascii_ident(code[i - 1])) {
            let (j, name) = ident_at(code, i);
            if PANIC_MACROS.contains(&name.as_str()) && code.get(j) == Some(&'!') {
                let k = skip_ws(code, j + 1);
                if matches!(code.get(k), Some('(') | Some('[') | Some('{')) {
                    sink.report(
                        code,
                        i,
                        j + 1,
                        "no-panic",
                        format!("`{name}!` in library code — use `debug_assert!` or a typed error"),
                    );
                }
            }
            i = j;
            continue;
        }
        i += 1;
    }
}

fn rule_float_accum(code: &[char], ranges: &[(String, usize, usize)], sink: &mut RuleSink) {
    let n = code.len();
    let mut i = 0;
    while i + 1 < n {
        if code[i] == '+' && code[i + 1] == '=' {
            let (_, le) = line_bounds(code, line_of(code, i));
            let rhs: String = code[(i + 2).min(le)..le].iter().collect();
            let rhs = rhs.trim().trim_end_matches(';').trim();
            let sanctioned = enclosing_fn(ranges, i).is_some_and(|f| SANCTIONED_FNS.contains(&f));
            if !is_int_literal(rhs) && !sanctioned {
                sink.report(
                    code,
                    i,
                    i + 2,
                    "float-accum",
                    "raw `+=` accumulation outside sanctioned reduction helpers".to_string(),
                );
            }
            i += 2;
            continue;
        }
        if code[i] == '.' {
            let j = skip_ws(code, i + 1);
            let (name_end, name) = ident_at(code, j);
            if name == "sum" {
                let mut k = skip_ws(code, name_end);
                // Optional turbofish `::<T>`.
                if code.get(k) == Some(&':') && code.get(k + 1) == Some(&':') {
                    let t = skip_ws(code, k + 2);
                    if code.get(t) == Some(&'<') {
                        if let Some(gt) = (t..n).find(|&p| code[p] == '>') {
                            k = skip_ws(code, gt + 1);
                        }
                    }
                }
                if code.get(k) == Some(&'(') {
                    let sanctioned =
                        enclosing_fn(ranges, i).is_some_and(|f| SANCTIONED_FNS.contains(&f));
                    if !sanctioned {
                        sink.report(
                            code,
                            i,
                            name_end,
                            "float-accum",
                            "`.sum()` reduction outside sanctioned helpers — order must be pinned"
                                .to_string(),
                        );
                    }
                }
            }
        }
        i += 1;
    }
}

fn rule_nondeterminism(code: &[char], sink: &mut RuleSink) {
    let mut i = 0;
    while i < code.len() {
        if is_ascii_ident(code[i]) && (i == 0 || !is_ascii_ident(code[i - 1])) {
            let (j, name) = ident_at(code, i);
            match name.as_str() {
                "HashMap" => sink.report(
                    code,
                    i,
                    j,
                    "nondeterminism",
                    "`HashMap` on a solver path — use `BTreeMap` or waive (lookup-only)"
                        .to_string(),
                ),
                "SystemTime" | "Instant" => sink.report(
                    code,
                    i,
                    j,
                    "nondeterminism",
                    format!("`{name}` outside bench/ or obs/ — wall-clock on a solver path"),
                ),
                _ => {}
            }
            i = j;
            continue;
        }
        i += 1;
    }
}

fn rule_fail_closed(code: &[char], sink: &mut RuleSink) {
    let n = code.len();
    let mut i = 0;
    while i < n {
        if code[i] == 'p'
            && (i == 0 || !is_ascii_ident(code[i - 1]))
            && code[i..].starts_with(&['p', 'u', 'b'])
            && code.get(i + 3).is_some_and(|c| c.is_whitespace())
        {
            let j = skip_ws(code, i + 3);
            if code[j..].starts_with(&['f', 'n'])
                && code.get(j + 2).is_some_and(|c| c.is_whitespace())
            {
                let k = skip_ws(code, j + 2);
                let (name_end, name) = ident_at(code, k);
                if !name.is_empty() {
                    let lower = name.to_lowercase();
                    if DECODER_NAMES.iter().any(|d| lower.contains(d)) {
                        let mut e = name_end;
                        while e < n && code[e] != '{' && code[e] != ';' {
                            e += 1;
                        }
                        let sig: String = code[i..e].iter().collect();
                        if !sig.contains("Result") {
                            sink.report(
                                code,
                                i,
                                name_end,
                                "fail-closed",
                                format!("decoder `pub fn {name}` must return `Result`"),
                            );
                        }
                    }
                }
            }
        }
        i += 1;
    }
}

/// One side of a `*`/`+`: its decisive identifier (last path segment),
/// plus float-literal / lifetime-or-type context flags.
#[derive(Debug, Default)]
struct Operand {
    name: String,
    is_float: bool,
    skip_op: bool,
}

fn marker_name(name: &str) -> bool {
    !name.is_empty()
        && SIZE_MARKERS.iter().any(|m| {
            name == *m
                || (name.len() > m.len() + 1
                    && (name.ends_with(m) && name.as_bytes()[name.len() - m.len() - 1] == b'_'
                        || name.starts_with(m) && name.as_bytes()[m.len()] == b'_'))
        })
}

fn float_ident(name: &str) -> bool {
    name == "f32" || name == "f64" || name.ends_with("f32") || name.ends_with("f64")
}

fn left_operand(code: &[char], op: usize) -> Operand {
    let mut o = Operand::default();
    let mut p = op;
    while p > 0 && code[p - 1].is_whitespace() {
        p -= 1;
    }
    if p == 0 {
        o.skip_op = true;
        return o;
    }
    let last = code[p - 1];
    if last == ')' || last == ']' {
        let open = if last == ')' { '(' } else { '[' };
        let mut depth = 1i64;
        let mut q = p - 1;
        while q > 0 {
            q -= 1;
            if code[q] == last {
                depth += 1;
            } else if code[q] == open {
                depth -= 1;
                if depth == 0 {
                    break;
                }
            }
        }
        if q > 0 && is_ascii_ident(code[q - 1]) {
            let mut s = q - 1;
            while s > 0 && is_ascii_ident(code[s - 1]) {
                s -= 1;
            }
            o.name = code[s..q].iter().collect();
        }
    } else if is_ascii_ident(last) {
        let mut s = p - 1;
        while s > 0 && is_ascii_ident(code[s - 1]) {
            s -= 1;
        }
        let name: String = code[s..p].iter().collect();
        if s > 0 && code[s - 1] == '\'' {
            // Lifetime in a bound position — type context, not arithmetic.
            o.skip_op = true;
            return o;
        }
        if name.chars().next().is_some_and(|c| c.is_ascii_digit()) {
            // Numeric literal; float when it carries a fractional part
            // or an f32/f64 suffix.
            if float_ident(&name) || (s > 1 && code[s - 1] == '.' && code[s - 2].is_ascii_digit())
            {
                o.is_float = true;
            }
            return o; // literal: never a size marker
        }
        if float_ident(&name) {
            // `as f64 *` — cast to float, float arithmetic.
            o.is_float = true;
            return o;
        }
        o.name = name;
    }
    o
}

fn right_operand(code: &[char], after_op: usize) -> Operand {
    let mut o = Operand::default();
    let n = code.len();
    let q = skip_ws(code, after_op);
    if q >= n || !is_ascii_ident(code[q]) {
        return o;
    }
    let (mut r, mut name) = ident_at(code, q);
    if name.chars().next().is_some_and(|c| c.is_ascii_digit()) {
        if float_ident(&name) || (r + 1 < n && code[r] == '.' && code[r + 1].is_ascii_digit()) {
            o.is_float = true;
        }
        return o; // literal
    }
    if float_ident(&name) {
        o.is_float = true;
        return o;
    }
    // Chase the path to its decisive last segment: `self.n`, `chunk.cols()`.
    loop {
        let t = skip_ws(code, r);
        if t < n && code[t] == '.' {
            let u = skip_ws(code, t + 1);
            if u < n && is_ascii_ident(code[u]) {
                let (r2, seg) = ident_at(code, u);
                if seg.chars().next().is_some_and(|c| c.is_ascii_digit()) {
                    break; // tuple index or digit segment — stop
                }
                name = seg;
                r = r2;
                continue;
            }
        }
        break;
    }
    o.name = name;
    o
}

fn rule_unchecked_arith(code: &[char], sink: &mut RuleSink) {
    let n = code.len();
    for i in 0..n {
        let opch = code[i];
        if opch != '*' && opch != '+' {
            continue;
        }
        if i + 1 < n && code[i + 1] == '=' {
            continue; // compound assignment: float-accum's turf
        }
        // Binary position: the previous non-ws char ends an expression.
        let mut p = i;
        while p > 0 && code[p - 1].is_whitespace() {
            p -= 1;
        }
        if p == 0 {
            continue;
        }
        let prev = code[p - 1];
        if !(is_ascii_ident(prev) || prev == ')' || prev == ']') {
            continue; // unary deref/plus, reference, range, cast, …
        }
        let l = left_operand(code, i);
        let r = right_operand(code, i + 1);
        if l.skip_op || l.is_float || r.is_float {
            continue;
        }
        let type_ctx = |s: &str| s.chars().next().is_some_and(|c| c.is_ascii_uppercase());
        if type_ctx(&l.name) || type_ctx(&r.name) {
            continue; // trait bound / type sum, not value arithmetic
        }
        let lm = marker_name(&l.name);
        let rm = marker_name(&r.name);
        let fires = if opch == '*' { lm || rm } else { lm && rm };
        if fires {
            let opword = if opch == '*' { "mul" } else { "add" };
            let show = |s: &str| if s.is_empty() { "?".to_string() } else { s.to_string() };
            sink.report(
                code,
                i,
                i + 1,
                "unchecked-arith",
                format!(
                    "unchecked `{opch}` on size arithmetic ({} {opch} {}) — use checked_{opword}/saturating_{opword} or a waiver",
                    show(&l.name),
                    show(&r.name)
                ),
            );
        }
    }
}

/// A `.lock()` / `.try_lock()` acquisition site.
struct LockSite {
    /// Char offset of the `.` before `lock`.
    dot: usize,
    /// End of the `lock` ident.
    name_end: usize,
    /// The mutex's decisive name (`self.rx.lock()` → `rx`).
    lock_name: String,
    /// Guard liveness extent `[dot, end)`.
    end: usize,
}

fn lock_sites(code: &[char]) -> Vec<LockSite> {
    let n = code.len();
    let mut out = Vec::new();
    let mut i = 0;
    while i < n {
        if code[i] != '.' {
            i += 1;
            continue;
        }
        let j = skip_ws(code, i + 1);
        let (k, name) = ident_at(code, j);
        if (name != "lock" && name != "try_lock") || code.get(skip_ws(code, k)) != Some(&'(') {
            i += 1;
            continue;
        }
        // Mutex name: the ident (or call result) just before the dot.
        let mut p = i;
        while p > 0 && code[p - 1].is_whitespace() {
            p -= 1;
        }
        let mut lock_name = String::new();
        if p > 0 {
            let last = code[p - 1];
            if last == ')' || last == ']' {
                let open = if last == ')' { '(' } else { '[' };
                let mut depth = 1i64;
                let mut q = p - 1;
                while q > 0 {
                    q -= 1;
                    if code[q] == last {
                        depth += 1;
                    } else if code[q] == open {
                        depth -= 1;
                        if depth == 0 {
                            break;
                        }
                    }
                }
                if q > 0 && is_ascii_ident(code[q - 1]) {
                    let mut s = q - 1;
                    while s > 0 && is_ascii_ident(code[s - 1]) {
                        s -= 1;
                    }
                    lock_name = code[s..q].iter().collect();
                }
            } else if is_ascii_ident(last) {
                let mut s = p - 1;
                while s > 0 && is_ascii_ident(code[s - 1]) {
                    s -= 1;
                }
                lock_name = code[s..p].iter().collect();
            }
        }
        // Binding: `let NAME = ….lock()…` extends the guard to the end
        // of the enclosing block (or an explicit `drop(NAME)`); an
        // inline temporary lives to the end of its statement.
        let mut stmt_start = 0;
        let mut q = i;
        while q > 0 {
            q -= 1;
            if code[q] == ';' || code[q] == '{' || code[q] == '}' {
                stmt_start = q + 1;
                break;
            }
        }
        let s0 = skip_ws(code, stmt_start);
        let (after_let, kw) = ident_at(code, s0);
        let binding = if kw == "let" {
            let b0 = skip_ws(code, after_let);
            let (b1, mut b) = ident_at(code, b0);
            if b == "mut" {
                let b2 = skip_ws(code, b1);
                b = ident_at(code, b2).1;
            }
            Some(b)
        } else {
            None
        };
        let mut end = n;
        let mut depth = 0i64;
        let mut m = k;
        while m < n {
            let ch = code[m];
            if ch == '{' {
                depth += 1;
            } else if ch == '}' {
                if depth == 0 {
                    end = m;
                    break;
                }
                depth -= 1;
            } else if ch == ';' && depth == 0 && binding.is_none() {
                end = m;
                break;
            } else if let Some(b) = &binding {
                if is_ascii_ident(ch)
                    && (m == 0 || !is_ascii_ident(code[m - 1]))
                    && depth >= 0
                {
                    let (m2, word) = ident_at(code, m);
                    if word == "drop" {
                        let a = skip_ws(code, m2);
                        if code.get(a) == Some(&'(') {
                            let (_, arg) = ident_at(code, skip_ws(code, a + 1));
                            if &arg == b {
                                end = m;
                                break;
                            }
                        }
                    }
                    m = m2;
                    continue;
                }
            }
            m += 1;
        }
        out.push(LockSite { dot: i, name_end: k, lock_name, end });
        i = k;
    }
    out
}

fn rule_lock_hygiene(code: &[char], orders: &[LockOrder], sink: &mut RuleSink) {
    let sites = lock_sites(code);
    if sites.is_empty() {
        for extra in orders.iter().skip(1) {
            sink.report(
                code,
                extra.span.0,
                extra.span.1,
                "lock-hygiene",
                "duplicate lock-order declaration".to_string(),
            );
        }
        return;
    }
    if orders.is_empty() {
        let first = &sites[0];
        sink.report(
            code,
            first.dot,
            first.name_end,
            "lock-hygiene",
            "file acquires locks but declares no canonical order — add a lock-order header comment"
                .to_string(),
        );
        return;
    }
    for extra in orders.iter().skip(1) {
        sink.report(
            code,
            extra.span.0,
            extra.span.1,
            "lock-hygiene",
            "duplicate lock-order declaration".to_string(),
        );
    }
    let order = &orders[0].names;
    let idx_of = |name: &str| order.iter().position(|n| n == name);
    for site in &sites {
        if idx_of(&site.lock_name).is_none() {
            sink.report(
                code,
                site.dot,
                site.name_end,
                "lock-hygiene",
                format!("lock `{}` is not in the declared lock-order", site.lock_name),
            );
        }
    }
    for outer in &sites {
        // Channel traffic while the guard is live.
        let mut j = outer.name_end;
        while j < outer.end.min(code.len()) {
            if code[j] != '.' {
                j += 1;
                continue;
            }
            let a = skip_ws(code, j + 1);
            let (b, m) = ident_at(code, a);
            if CHANNEL_METHODS.contains(&m.as_str()) && code.get(skip_ws(code, b)) == Some(&'(') {
                sink.report(
                    code,
                    j,
                    b,
                    "lock-hygiene",
                    format!(
                        "channel `.{m}()` while holding lock `{}` — drop the guard first",
                        outer.lock_name
                    ),
                );
            }
            j = b.max(j + 1);
        }
        // Nested acquisition against the declared order.
        for inner in &sites {
            if inner.dot <= outer.dot || inner.dot >= outer.end {
                continue;
            }
            if let (Some(oi), Some(ii)) = (idx_of(&outer.lock_name), idx_of(&inner.lock_name)) {
                if ii <= oi {
                    sink.report(
                        code,
                        inner.dot,
                        inner.name_end,
                        "lock-hygiene",
                        format!(
                            "lock `{}` acquired while holding `{}` violates the declared lock-order",
                            inner.lock_name, outer.lock_name
                        ),
                    );
                }
            }
        }
    }
}

fn apply_waivers(viol: &mut [Violation], waivers: &mut Waivers) {
    for v in viol.iter_mut() {
        let mut hit = false;
        for w in waivers.scoped.iter_mut() {
            if w.line_start <= v.line && v.line <= w.line_end {
                if let Some(ix) = w.rules.iter().position(|r| r == v.rule) {
                    v.waived = true;
                    w.used[ix] = true;
                    hit = true;
                    break;
                }
            }
        }
        if hit {
            continue;
        }
        for w in waivers.file_wide.iter_mut() {
            if let Some(ix) = w.rules.iter().position(|r| r == v.rule) {
                v.waived = true;
                w.used[ix] = true;
                break;
            }
        }
    }
}

fn stale_violations(waivers: &Waivers, out: &mut Vec<Violation>) {
    for w in waivers.scoped.iter().chain(waivers.file_wide.iter()) {
        for (ix, rule) in w.rules.iter().enumerate() {
            if w.used[ix] {
                continue;
            }
            let what = if w.file_wide {
                format!("stale waiver: allow-file({rule}) no longer suppresses anything in this file — delete it")
            } else {
                format!(
                    "stale waiver: allow({rule}) no longer suppresses anything at its site — delete it"
                )
            };
            out.push(Violation {
                path: String::new(),
                line: w.line,
                span: w.span,
                rule: "stale-waiver",
                msg: what,
                waived: false,
            });
        }
    }
}

fn lint_impl(rel: &str, src: &str, self_mode: bool) -> Vec<Violation> {
    let stripped = strip_source(src);
    let mut waivers = scan_waivers(&stripped.code, &stripped.comments);
    let mut code = stripped.code;
    blank_cfg_test(&mut code);
    let ranges = fn_ranges(&code);
    let mut sink = RuleSink { viol: Vec::new() };

    rule_no_panic(&code, &mut sink);
    if self_mode {
        rule_fail_closed(&code, &mut sink);
    } else {
        if rel.starts_with("backend/") || rel.starts_with("linalg/") || rel == "data/stats.rs" {
            rule_float_accum(&code, &ranges, &mut sink);
        }
        if !(rel.starts_with("bench/") || rel.starts_with("obs/")) {
            rule_nondeterminism(&code, &mut sink);
        }
        if rel.starts_with("data/") || rel.starts_with("registry/") || rel == "util/json.rs" {
            rule_fail_closed(&code, &mut sink);
        }
        if (rel.starts_with("data/") && rel != "data/stats.rs")
            || rel == "util/json.rs"
            || rel.starts_with("daemon/")
            || rel.starts_with("registry/")
        {
            rule_unchecked_arith(&code, &mut sink);
        }
        if rel == "backend/pool.rs" || rel.starts_with("coordinator/") || rel.starts_with("daemon/")
        {
            rule_lock_hygiene(&code, &waivers.lock_orders, &mut sink);
        }
    }

    let mut viol = sink.viol;
    apply_waivers(&mut viol, &mut waivers);
    for (line, span, msg) in waivers.bad.drain(..) {
        viol.push(Violation { path: String::new(), line, span, rule: "bad-waiver", msg, waived: false });
    }
    stale_violations(&waivers, &mut viol);
    for v in viol.iter_mut() {
        v.path = rel.to_string();
    }
    viol.sort();
    viol
}

/// Lint one workspace source file under every rule its path is scoped
/// to, returning **all** violations — waived ones carry `waived: true`.
/// `rel` is the path relative to `rust/src`, with `/` separators.
pub fn lint_file_full(rel: &str, src: &str) -> Vec<Violation> {
    lint_impl(rel, src, false)
}

/// [`lint_file_full`] filtered to unwaived violations — the gate the
/// CLI exit code and the fixture tests are built on.
pub fn lint_file(rel: &str, src: &str) -> Vec<Violation> {
    lint_file_full(rel, src).into_iter().filter(|v| !v.waived).collect()
}

/// Self-lint for the lint tool's own sources: `no-panic` and
/// `fail-closed` (whole-file scope) plus the waiver machinery — the
/// analyzer is held to its own fail-closed bar.
pub fn lint_self_file(rel: &str, src: &str) -> Vec<Violation> {
    lint_impl(rel, src, true)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn strings_and_comments_are_blanked() {
        let src = "let s = \"panic!(\"; // .unwrap()\nlet c = '\\'';";
        let st = strip_source(src);
        let text: String = st.code.iter().collect();
        assert!(!text.contains("panic"));
        assert!(!text.contains("unwrap"));
        assert_eq!(st.comments.len(), 1);
        assert_eq!(st.strings.len(), 1);
        assert_eq!(st.strings[0].1, "panic!(");
        assert_eq!(text.matches('\n').count(), src.matches('\n').count());
    }

    #[test]
    fn raw_strings_preserve_length() {
        let src = "let s = r#\"has .unwrap() inside\"#; x.unwrap();";
        let st = strip_source(src);
        assert_eq!(st.code.len(), src.chars().count());
        let text: String = st.code.iter().collect();
        assert_eq!(text.matches("unwrap").count(), 1);
        assert_eq!(st.strings.len(), 1);
        assert!(st.strings[0].1.contains(".unwrap() inside"));
    }

    #[test]
    fn int_literals() {
        assert!(is_int_literal("1"));
        assert!(is_int_literal("2_000"));
        assert!(is_int_literal("7usize"));
        assert!(!is_int_literal("x"));
        assert!(!is_int_literal("1.0"));
        assert!(!is_int_literal(""));
    }

    #[test]
    fn lifetime_is_not_a_char_literal() {
        let src = "fn f<'a>(x: &'a str) { x.expect(\"e\"); }";
        let v = lint_file("x.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "no-panic");
        assert!(v[0].span.0 < v[0].span.1);
    }

    #[test]
    fn assert_eq_is_not_bare_assert() {
        let v = lint_file("x.rs", "fn f() { assert_eq!(1, 1); debug_assert!(true); }");
        assert!(v.is_empty(), "{v:?}");
    }

    #[test]
    fn unchecked_arith_needs_size_markers() {
        // `*` with one size-typed side fires; `+` needs both sides.
        let fire = "fn f(n: usize) -> usize { n * 8 }";
        let v = lint_file("data/x.rs", fire);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "unchecked-arith");

        let both = "fn f(off: usize, len: usize) -> usize { off + len }";
        let v = lint_file("data/x.rs", both);
        assert_eq!(v.len(), 1, "{v:?}");

        let counter = "fn f(t: usize, j: usize) -> usize { t + j }";
        assert!(lint_file("data/x.rs", counter).is_empty());

        let float = "fn f(n: usize) -> f64 { n as f64 * 2.0 }";
        assert!(lint_file("data/x.rs", float).is_empty());

        let checked = "fn f(n: usize) -> Option<usize> { n.checked_mul(8) }";
        assert!(lint_file("data/x.rs", checked).is_empty());

        // Out of scope: not a decoder path.
        assert!(lint_file("ica/x.rs", fire).is_empty());
        assert!(lint_file("data/stats.rs", fire).is_empty());

        // The daemon's wire decoders are in scope: frame-length
        // arithmetic must be checked.
        let v = lint_file("daemon/wire.rs", fire);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "unchecked-arith");
        assert!(lint_file("daemon/core.rs", checked).is_empty());
    }

    #[test]
    fn registry_paths_are_decoder_scoped() {
        // The registry parses manifests and artifacts off disk, so both
        // decoder rules apply under registry/: size arithmetic must be
        // checked and decoder-shaped pub fns must return Result.
        let arith = "fn f(n: usize) -> usize { n * 8 }";
        let v = lint_file("registry/manifest.rs", arith);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "unchecked-arith");

        let infallible = "pub fn parse_manifest(s: &str) -> u32 { s.len() as u32 }\n";
        let v = lint_file("registry/store.rs", infallible);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "fail-closed");
        assert!(v[0].msg.contains("must return `Result`"), "{}", v[0].msg);

        let fallible =
            "pub fn parse_manifest(s: &str) -> Result<u32, E> { Ok(s.len() as u32) }\n";
        assert!(lint_file("registry/store.rs", fallible).is_empty());
    }

    #[test]
    fn lock_hygiene_channel_under_guard() {
        let src = "// fica-lint: lock-order(rx)\nfn f(rx: &M) { let g = rx.lock(); g.recv(); }\n";
        let v = lint_file("coordinator/x.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "lock-hygiene");
        assert!(v[0].msg.contains("recv"), "{}", v[0].msg);
    }

    #[test]
    fn lock_hygiene_requires_declaration() {
        let src = "fn f(rx: &M) { let g = rx.lock(); }\n";
        let v = lint_file("coordinator/x.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert!(v[0].msg.contains("no canonical order"), "{}", v[0].msg);
    }

    #[test]
    fn stale_waiver_fires_when_nothing_is_suppressed() {
        let src = "// fica-lint: allow(no-panic) — nothing here panics anymore\nfn f() -> u32 { 1 }\n";
        let v = lint_file("ica/x.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "stale-waiver");
        assert_eq!(v[0].line, 1);
    }

    #[test]
    fn live_waiver_is_not_stale_and_is_kept_in_full_output() {
        let src = "fn f(v: Option<u32>) -> u32 { v.unwrap() } // fica-lint: allow(no-panic) — fixture\n";
        assert!(lint_file("ica/x.rs", src).is_empty());
        let full = lint_file_full("ica/x.rs", src);
        assert_eq!(full.len(), 1, "{full:?}");
        assert!(full[0].waived);
        assert_eq!(full[0].rule, "no-panic");
    }

    #[test]
    fn waiving_an_unwaivable_rule_is_bad() {
        let src = "// fica-lint: allow(schema-drift) — can't silence drift\nfn f() {}\n";
        let v = lint_file("ica/x.rs", src);
        assert_eq!(v.len(), 1, "{v:?}");
        assert_eq!(v[0].rule, "bad-waiver");
    }

    #[test]
    fn items_are_scanned_with_spans() {
        let src = "pub struct P(usize);\nimpl P { pub fn get(&self) -> usize { self.0 } }\nconst N_SCHEMA: &str = \"x\";\n";
        let st = strip_source(src);
        let items = scan_items(&st.code, &[]);
        let kinds: Vec<&str> = items.iter().map(|i| i.kind.as_str()).collect();
        assert_eq!(kinds, vec!["struct", "impl", "fn", "const"], "{items:?}");
        assert_eq!(items[0].name, "P");
        assert_eq!(items[1].name, "P");
        assert_eq!(items[2].name, "get");
        assert_eq!(items[3].name, "N_SCHEMA");
        assert!(items[1].start < items[2].start && items[2].end <= items[1].end);
    }

    #[test]
    fn calls_are_scanned() {
        let src = "fn f() { g(); h.i(); if x { j() } }";
        let st = strip_source(src);
        let names: Vec<String> = scan_calls(&st.code).into_iter().map(|(_, n)| n).collect();
        assert_eq!(names, vec!["g", "i", "j"]);
    }
}

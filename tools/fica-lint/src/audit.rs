//! Cross-file audit stage: load the whole workspace (solver sources,
//! integration tests, fixtures, docs) into one model and run the rules
//! no single file can check — `schema-drift`, `contract-coverage` —
//! plus the per-file token rules over every solver source.
//!
//! The model is deliberately plain: a sorted `path -> content` map.
//! Everything downstream (tag scans, the item graph, the test index)
//! is derived per call; the whole tree is a few hundred kilobytes and
//! the audit runs in milliseconds, so there is nothing to cache.

use std::collections::{BTreeMap, BTreeSet};
use std::path::{Path, PathBuf};

use crate::{blank_cfg_test, lint_file_full, line_of, scan_items, strip_source, ItemKind, Violation};

/// The loaded workspace: workspace-relative path (with `/` separators)
/// to file content.
#[derive(Debug, Default)]
pub struct Workspace {
    /// `rust/src/**.rs`, `rust/tests/**.{rs,json}`, `docs/**.md`,
    /// `ARCHITECTURE.md`, `README.md`.
    pub files: BTreeMap<String, String>,
}

fn walk_tree(
    dir: &Path,
    prefix: &str,
    exts: &[&str],
    out: &mut BTreeMap<String, String>,
) -> Result<(), String> {
    let entries = match std::fs::read_dir(dir) {
        Ok(e) => e,
        Err(_) => return Ok(()), // optional subtree
    };
    let mut paths: Vec<PathBuf> = Vec::new();
    for entry in entries {
        let entry = entry.map_err(|e| format!("walking {}: {e}", dir.display()))?;
        paths.push(entry.path());
    }
    paths.sort();
    for path in paths {
        let name = path.file_name().map(|n| n.to_string_lossy().into_owned());
        let Some(name) = name else { continue };
        let rel = format!("{prefix}/{name}");
        if path.is_dir() {
            walk_tree(&path, &rel, exts, out)?;
        } else if path.extension().is_some_and(|e| exts.iter().any(|x| e == *x)) {
            let src = std::fs::read_to_string(&path)
                .map_err(|e| format!("reading {}: {e}", path.display()))?;
            out.insert(rel, src);
        }
    }
    Ok(())
}

impl Workspace {
    /// Load every audited file under the workspace root. Fails closed
    /// on unreadable files; `rust/src` must exist, everything else is
    /// optional (and its absence is then `contract-coverage`'s problem).
    pub fn load(root: &Path) -> Result<Workspace, String> {
        if !root.join("rust/src").is_dir() {
            return Err(format!(
                "{} has no rust/src — not a faster-ica workspace root",
                root.display()
            ));
        }
        let mut files = BTreeMap::new();
        walk_tree(&root.join("rust/src"), "rust/src", &["rs"], &mut files)?;
        walk_tree(&root.join("rust/tests"), "rust/tests", &["rs", "json"], &mut files)?;
        walk_tree(&root.join("docs"), "docs", &["md"], &mut files)?;
        for top in ["ARCHITECTURE.md", "README.md"] {
            if let Ok(src) = std::fs::read_to_string(root.join(top)) {
                files.insert(top.to_string(), src);
            }
        }
        Ok(Workspace { files })
    }

    /// Build a workspace directly from `(path, content)` pairs — the
    /// unit-test entry point.
    pub fn from_entries(entries: Vec<(String, String)>) -> Workspace {
        Workspace { files: entries.into_iter().collect() }
    }
}

/// Nearest ancestor of `start` whose `Cargo.toml` declares
/// `[workspace]` — the root every rule scope is pinned to, so the CLI
/// behaves identically from any invocation directory.
pub fn discover_root(start: &Path) -> Option<PathBuf> {
    start.ancestors().find_map(|dir| {
        let manifest = dir.join("Cargo.toml");
        match std::fs::read_to_string(&manifest) {
            Ok(text) if text.contains("[workspace]") => Some(dir.to_path_buf()),
            _ => None,
        }
    })
}

/// One `fica.<family>/vN` tag occurrence: `(start, end, family, version)`.
type Tag = (usize, usize, String, u64);

/// Scan text for schema tags `fica.<family>/vN`.
fn scan_tags(chars: &[char]) -> Vec<Tag> {
    let head: Vec<char> = "fica.".chars().collect();
    let n = chars.len();
    let mut out = Vec::new();
    let mut i = 0;
    while i + head.len() < n {
        if chars[i..i + head.len()] != head[..]
            || (i > 0 && (chars[i - 1].is_ascii_alphanumeric() || chars[i - 1] == '_'))
        {
            i += 1;
            continue;
        }
        let mut j = i + head.len();
        let fam_start = j;
        while j < n && (chars[j].is_ascii_lowercase() || chars[j].is_ascii_digit() || chars[j] == '_')
        {
            j += 1;
        }
        if j == fam_start || j + 1 >= n || chars[j] != '/' || chars[j + 1] != 'v' {
            i += 1;
            continue;
        }
        let fam: String = chars[fam_start..j].iter().collect();
        let mut k = j + 2;
        let mut ver: u64 = 0;
        let digits_start = k;
        while k < n && chars[k].is_ascii_digit() {
            ver = ver.saturating_mul(10).saturating_add(chars[k] as u64 - '0' as u64);
            k += 1;
        }
        if k == digits_start {
            i += 1;
            continue;
        }
        out.push((i, k, fam, ver));
        i = k;
    }
    out
}

fn in_regions(regions: &[(usize, usize)], off: usize) -> bool {
    regions.iter().any(|&(a, b)| a <= off && off < b)
}

fn mk(path: &str, chars: &[char], span: (usize, usize), rule: &'static str, msg: String) -> Violation {
    Violation { path: path.to_string(), line: line_of(chars, span.0), span, rule, msg, waived: false }
}

/// Backticked `identifier` tokens in a table cell (word-shaped only —
/// paths and expressions are presentation, not contract symbols).
fn backticked_idents(cell: &str) -> Vec<String> {
    let chars: Vec<char> = cell.chars().collect();
    let mut out = Vec::new();
    let mut i = 0;
    while i < chars.len() {
        if chars[i] != '`' {
            i += 1;
            continue;
        }
        let start = i + 1;
        let mut j = start;
        while j < chars.len() && chars[j] != '`' {
            j += 1;
        }
        if j >= chars.len() {
            break;
        }
        let tok: String = chars[start..j].iter().collect();
        if !tok.is_empty() && tok.chars().all(|c| c.is_ascii_alphanumeric() || c == '_') {
            out.push(tok);
        }
        i = j + 1;
    }
    out
}

const CONTRACT_HEADER: &str = "| paths compared | guarantee | why | pinned by |";

fn rule_schema_drift(ws: &Workspace, viol: &mut Vec<Violation>) {
    // Code tags: string literals in non-test rust/src code.
    let mut code_versions: BTreeMap<String, BTreeSet<u64>> = BTreeMap::new();
    let mut code_sites: Vec<(String, (usize, usize), String, u64)> = Vec::new();
    let mut schema_consts: Vec<(String, (usize, usize), String, bool)> = Vec::new();
    for (path, src) in &ws.files {
        if !(path.starts_with("rust/src/") && path.ends_with(".rs")) {
            continue;
        }
        let stripped = strip_source(src);
        let mut erased = stripped.code.clone();
        let regions = blank_cfg_test(&mut erased);
        let mut tags_here: Vec<Tag> = Vec::new();
        for (off, content) in &stripped.strings {
            if in_regions(&regions, *off) {
                continue;
            }
            let cchars: Vec<char> = content.chars().collect();
            for (a, b, fam, ver) in scan_tags(&cchars) {
                tags_here.push((off + a, off + b, fam, ver));
            }
        }
        for (a, b, fam, ver) in &tags_here {
            code_versions.entry(fam.clone()).or_default().insert(*ver);
            code_sites.push((path.clone(), (*a, *b), fam.clone(), *ver));
        }
        // Schema-named consts must carry a tag in their initializer.
        for item in scan_items(&stripped.code, &regions) {
            if item.kind == ItemKind::Const && !item.in_test && item.name.contains("_SCHEMA") {
                let tagged =
                    tags_here.iter().any(|(a, _, _, _)| item.start <= *a && *a < item.end);
                schema_consts.push((path.clone(), (item.start, item.end), item.name, tagged));
            }
        }
    }

    // Doc tags: docs/*.md plus the top-level narrative docs.
    let mut doc_tags: BTreeSet<(String, u64)> = BTreeSet::new();
    let mut doc_sites: Vec<(String, (usize, usize), String, u64)> = Vec::new();
    for (path, src) in &ws.files {
        let is_doc = (path.starts_with("docs/") && path.ends_with(".md"))
            || path == "ARCHITECTURE.md"
            || path == "README.md";
        if !is_doc {
            continue;
        }
        let chars: Vec<char> = src.chars().collect();
        for (a, b, fam, ver) in scan_tags(&chars) {
            doc_tags.insert((fam.clone(), ver));
            doc_sites.push((path.clone(), (a, b), fam, ver));
        }
    }

    // (a) every code tag must be documented.
    for (path, span, fam, ver) in &code_sites {
        if !doc_tags.contains(&(fam.clone(), *ver)) {
            let chars: Vec<char> = ws.files[path].chars().collect();
            viol.push(mk(
                path,
                &chars,
                *span,
                "schema-drift",
                format!(
                    "schema tag `fica.{fam}/v{ver}` in code is not documented under docs/ — update the schema docs"
                ),
            ));
        }
    }
    // (b) no doc tag may outrun the code for a family the code writes.
    for (path, span, fam, ver) in &doc_sites {
        if let Some(vers) = code_versions.get(fam) {
            let max = vers.iter().next_back().copied().unwrap_or(0);
            if *ver > max {
                let chars: Vec<char> = ws.files[path].chars().collect();
                viol.push(mk(
                    path,
                    &chars,
                    *span,
                    "schema-drift",
                    format!(
                        "documented schema tag `fica.{fam}/v{ver}` has no code writer (max code version is v{max}) — docs and code have drifted"
                    ),
                ));
            }
        }
    }
    // (c) fixture tags must match a code tag exactly.
    for (path, src) in &ws.files {
        if !(path.starts_with("rust/tests/fixtures/") && path.ends_with(".json")) {
            continue;
        }
        let chars: Vec<char> = src.chars().collect();
        for (a, b, fam, ver) in scan_tags(&chars) {
            let known = code_versions.get(&fam).is_some_and(|vs| vs.contains(&ver));
            if !known {
                viol.push(mk(
                    path,
                    &chars,
                    (a, b),
                    "schema-drift",
                    format!(
                        "fixture schema tag `fica.{fam}/v{ver}` matches no code tag — regenerate or retire the fixture"
                    ),
                ));
            }
        }
    }
    // (d) schema-named consts carry their tag.
    for (path, span, name, tagged) in &schema_consts {
        if !tagged {
            let chars: Vec<char> = ws.files[path].chars().collect();
            viol.push(mk(
                path,
                &chars,
                *span,
                "schema-drift",
                format!("const `{name}` is schema-named but contains no `fica.<family>/vN` tag"),
            ));
        }
    }
}

fn rule_contract_coverage(ws: &Workspace, viol: &mut Vec<Violation>) {
    // Test index: every fn in rust/tests plus every #[cfg(test)] fn in
    // rust/src, name -> concatenated raw body text.
    let mut index: BTreeMap<String, String> = BTreeMap::new();
    for (path, src) in &ws.files {
        if !path.ends_with(".rs") {
            continue;
        }
        let in_tests_tree = path.starts_with("rust/tests/");
        let in_src_tree = path.starts_with("rust/src/");
        if !in_tests_tree && !in_src_tree {
            continue;
        }
        let raw: Vec<char> = src.chars().collect();
        let stripped = strip_source(src);
        let mut erased = stripped.code.clone();
        let regions = blank_cfg_test(&mut erased);
        for item in scan_items(&stripped.code, &regions) {
            if item.kind != ItemKind::Fn {
                continue;
            }
            if in_src_tree && !item.in_test {
                continue;
            }
            let body: String = raw[item.start..item.end.min(raw.len())].iter().collect();
            let slot = index.entry(item.name).or_default();
            slot.push_str(&body);
            slot.push('\n');
        }
    }

    let arch_path = "ARCHITECTURE.md";
    let Some(arch) = ws.files.get(arch_path) else {
        viol.push(Violation {
            path: arch_path.to_string(),
            line: 1,
            span: (0, 0),
            rule: "contract-coverage",
            msg: "ARCHITECTURE.md not found — the equivalence-contract table is the coverage anchor"
                .to_string(),
            waived: false,
        });
        return;
    };
    let chars: Vec<char> = arch.chars().collect();
    let mut header_at: Option<usize> = None;
    let mut off = 0;
    for line in arch.split('\n') {
        if line.trim() == CONTRACT_HEADER {
            header_at = Some(off);
            break;
        }
        off += line.chars().count() + 1;
    }
    let Some(header_off) = header_at else {
        viol.push(Violation {
            path: arch_path.to_string(),
            line: 1,
            span: (0, 0),
            rule: "contract-coverage",
            msg: format!(
                "equivalence-contract table header `{CONTRACT_HEADER}` not found in ARCHITECTURE.md"
            ),
            waived: false,
        });
        return;
    };

    // Rows: contiguous `|`-prefixed lines after the header; the first
    // is the separator.
    let tail: String = chars[header_off..].iter().collect();
    let mut row_off = header_off;
    let mut first = true;
    for line in tail.split('\n') {
        let this_off = row_off;
        row_off += line.chars().count() + 1;
        if first {
            first = false; // the header line itself
            continue;
        }
        let trimmed = line.trim();
        if !trimmed.starts_with('|') {
            break;
        }
        if trimmed.chars().all(|c| c == '|' || c == '-' || c == ':' || c.is_whitespace()) {
            continue; // separator
        }
        let span = (this_off, this_off + line.chars().count());
        let cells: Vec<&str> = trimmed.trim_matches('|').split('|').map(|c| c.trim()).collect();
        if cells.len() < 4 {
            viol.push(mk(
                arch_path,
                &chars,
                span,
                "contract-coverage",
                "contract row is missing its `pinned by` cell".to_string(),
            ));
            continue;
        }
        let label = cells[0].replace('`', "");
        let pinned = backticked_idents(cells[3]);
        if pinned.is_empty() {
            viol.push(mk(
                arch_path,
                &chars,
                span,
                "contract-coverage",
                format!("contract row ({label}) pins no test — name the covering test fns in its `pinned by` cell"),
            ));
            continue;
        }
        let mut resolved = String::new();
        for tok in &pinned {
            match index.get(tok) {
                Some(body) => resolved.push_str(body),
                None => viol.push(mk(
                    arch_path,
                    &chars,
                    span,
                    "contract-coverage",
                    format!("contract row ({label}) pins `{tok}` but no such test fn exists"),
                )),
            }
        }
        if resolved.is_empty() {
            continue; // every pin dangled; already reported
        }
        for sym in backticked_idents(cells[0]) {
            if !resolved.contains(&sym) {
                viol.push(mk(
                    arch_path,
                    &chars,
                    span,
                    "contract-coverage",
                    format!("contract row ({label}) is pinned by tests that never mention `{sym}`"),
                ));
            }
        }
    }
}

/// Run the full audit: per-file token rules over every solver source,
/// then the cross-file rules over the whole model. Returns every
/// violation (waived ones flagged), sorted by (path, line, span, rule).
pub fn audit(ws: &Workspace) -> Vec<Violation> {
    let mut viol: Vec<Violation> = Vec::new();
    for (path, src) in &ws.files {
        if !(path.starts_with("rust/src/") && path.ends_with(".rs")) {
            continue;
        }
        let rel = &path["rust/src/".len()..];
        for mut v in lint_file_full(rel, src) {
            v.path = path.clone();
            viol.push(v);
        }
    }
    rule_schema_drift(ws, &mut viol);
    rule_contract_coverage(ws, &mut viol);
    viol.sort();
    viol
}

/// Human-readable report: unwaived violations as
/// `path:line: [rule] msg` lines plus a summary line.
pub fn render_text(viol: &[Violation], files: usize) -> String {
    let mut out = String::new();
    let mut n = 0usize;
    for v in viol.iter().filter(|v| !v.waived) {
        out.push_str(&format!("{}:{}: [{}] {}\n", v.path, v.line, v.rule, v.msg));
        n += 1;
    }
    if n > 0 {
        out.push_str(&format!("fica-lint: {n} violation(s)\n"));
    } else {
        out.push_str(&format!("fica-lint: clean ({files} files)\n"));
    }
    out
}

fn json_escape(s: &str) -> String {
    let mut out = String::new();
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out
}

/// Machine-readable `fica.lint/v1` report, byte-identical between this
/// crate and `mirror.py` (the CI parity gate diffs the two): every
/// violation — including waived ones — with path, line, span, rule,
/// waived flag and message.
pub fn render_json(viol: &[Violation], files: usize) -> String {
    let mut out = format!("{{\"schema\":\"fica.lint/v1\",\"files\":{files},\"violations\":[");
    for (ix, v) in viol.iter().enumerate() {
        if ix > 0 {
            out.push(',');
        }
        out.push_str(&format!(
            "\n{{\"path\":\"{}\",\"line\":{},\"span\":[{},{}],\"rule\":\"{}\",\"waived\":{},\"msg\":\"{}\"}}",
            json_escape(&v.path),
            v.line,
            v.span.0,
            v.span.1,
            v.rule,
            if v.waived { "true" } else { "false" },
            json_escape(&v.msg)
        ));
    }
    if viol.is_empty() {
        out.push_str("]}\n");
    } else {
        out.push_str("\n]}\n");
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    fn ws(entries: &[(&str, &str)]) -> Workspace {
        Workspace::from_entries(
            entries.iter().map(|(p, c)| (p.to_string(), c.to_string())).collect(),
        )
    }

    #[test]
    fn schema_tags_are_scanned() {
        let chars: Vec<char> = "x fica.trace/v1 y fica.bench_backend/v12 zfica.no/v1".chars().collect();
        let tags = scan_tags(&chars);
        assert_eq!(tags.len(), 2, "{tags:?}");
        assert_eq!(tags[0].2, "trace");
        assert_eq!(tags[0].3, 1);
        assert_eq!(tags[1].2, "bench_backend");
        assert_eq!(tags[1].3, 12);
    }

    #[test]
    fn undocumented_code_tag_drifts() {
        let w = ws(&[
            ("rust/src/lib.rs", "pub const DEMO_SCHEMA: &str = \"fica.demo/v2\";\n"),
            ("docs/DEMO.md", "The tag is `fica.demo/v1`.\n"),
            ("ARCHITECTURE.md", &format!("{CONTRACT_HEADER}\n")),
        ]);
        let v = audit(&w);
        let drift: Vec<&Violation> = v.iter().filter(|v| v.rule == "schema-drift").collect();
        // v2 in code undocumented + v1 in docs newer than nothing? No:
        // code has v2, docs have v1 <= 2 — only the undocumented v2 fires.
        assert_eq!(drift.len(), 1, "{v:?}");
        assert!(drift[0].msg.contains("fica.demo/v2"), "{}", drift[0].msg);
        assert_eq!(drift[0].path, "rust/src/lib.rs");
    }

    #[test]
    fn fixture_tag_must_match_code() {
        let w = ws(&[
            ("rust/src/lib.rs", "pub const DEMO_SCHEMA: &str = \"fica.demo/v1\";\n"),
            ("docs/DEMO.md", "`fica.demo/v1`\n"),
            ("rust/tests/fixtures/old.json", "{\"schema\":\"fica.demo/v9\"}\n"),
            ("ARCHITECTURE.md", &format!("{CONTRACT_HEADER}\n")),
        ]);
        let v = audit(&w);
        let drift: Vec<&Violation> = v.iter().filter(|v| v.rule == "schema-drift").collect();
        assert_eq!(drift.len(), 1, "{v:?}");
        assert_eq!(drift[0].path, "rust/tests/fixtures/old.json");
    }

    #[test]
    fn contract_row_needs_a_live_test() {
        let arch = format!(
            "{CONTRACT_HEADER}\n|---|---|---|---|\n| `alpha` vs beta | bitwise | speed | `test_alpha` |\n| gamma | 1e-12 | robust | `test_gone` |\n"
        );
        let w = ws(&[
            ("rust/src/lib.rs", "\n"),
            ("rust/tests/t.rs", "#[test]\nfn test_alpha() { let _ = \"alpha\"; }\n"),
            ("ARCHITECTURE.md", &arch),
        ]);
        let v = audit(&w);
        let cov: Vec<&Violation> = v.iter().filter(|v| v.rule == "contract-coverage").collect();
        assert_eq!(cov.len(), 1, "{v:?}");
        assert!(cov[0].msg.contains("test_gone"), "{}", cov[0].msg);
        assert_eq!(cov[0].line, 4);
    }

    #[test]
    fn contract_row_symbols_must_appear_in_pinning_tests() {
        let arch = format!(
            "{CONTRACT_HEADER}\n|---|---|---|---|\n| `Missing` path | bitwise | x | `test_a` |\n"
        );
        let w = ws(&[
            ("rust/src/lib.rs", "\n"),
            ("rust/tests/t.rs", "fn test_a() { other(); }\n"),
            ("ARCHITECTURE.md", &arch),
        ]);
        let v = audit(&w);
        let cov: Vec<&Violation> = v.iter().filter(|v| v.rule == "contract-coverage").collect();
        assert_eq!(cov.len(), 1, "{v:?}");
        assert!(cov[0].msg.contains("`Missing`"), "{}", cov[0].msg);
    }

    #[test]
    fn json_report_shape_is_stable() {
        let v = vec![Violation {
            path: "a.rs".to_string(),
            line: 3,
            span: (10, 12),
            rule: "no-panic",
            msg: "x \"y\"".to_string(),
            waived: true,
        }];
        let json = render_json(&v, 2);
        assert_eq!(
            json,
            "{\"schema\":\"fica.lint/v1\",\"files\":2,\"violations\":[\n{\"path\":\"a.rs\",\"line\":3,\"span\":[10,12],\"rule\":\"no-panic\",\"waived\":true,\"msg\":\"x \\\"y\\\"\"}\n]}\n"
        );
        assert_eq!(
            render_json(&[], 5),
            "{\"schema\":\"fica.lint/v1\",\"files\":5,\"violations\":[]}\n"
        );
    }
}

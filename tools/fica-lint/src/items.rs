//! Item-level source model: a lightweight parser that turns a stripped
//! source buffer (see [`crate::strip_source`]) into a flat list of
//! items — functions, types, impls, modules, imports, consts — each
//! with a char span and a test/non-test flag. The cross-file audit
//! stage ([`crate::audit`]) is built on this model: schema-drift walks
//! `*_SCHEMA` consts, contract-coverage indexes test functions.
//!
//! This is deliberately not a full parser: it scans for item keywords
//! at identifier boundaries in comment/string-blanked code and matches
//! braces forward. That is exact enough for span and name extraction on
//! the rustfmt-shaped code this workspace enforces, and it keeps the
//! crate dependency-free.

use crate::{ident_at, is_ascii_ident, match_brace, skip_ws};

/// What kind of item a span covers.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ItemKind {
    /// `fn` (free, method, or trait method with a body).
    Fn,
    /// `struct`.
    Struct,
    /// `enum`.
    Enum,
    /// `trait`.
    Trait,
    /// `impl` block.
    Impl,
    /// `mod` (inline or declaration).
    Mod,
    /// `use` import.
    Use,
    /// `const` item (not `const fn`, not a const generic).
    Const,
    /// `static` item.
    Static,
    /// `type` alias.
    TypeAlias,
}

impl ItemKind {
    /// Stable lowercase name, shared with `mirror.py`.
    pub fn as_str(self) -> &'static str {
        match self {
            ItemKind::Fn => "fn",
            ItemKind::Struct => "struct",
            ItemKind::Enum => "enum",
            ItemKind::Trait => "trait",
            ItemKind::Impl => "impl",
            ItemKind::Mod => "mod",
            ItemKind::Use => "use",
            ItemKind::Const => "const",
            ItemKind::Static => "static",
            ItemKind::TypeAlias => "type",
        }
    }
}

/// One parsed item.
#[derive(Debug, Clone)]
pub struct Item {
    /// Item kind.
    pub kind: ItemKind,
    /// Declared name (for `impl`: the implemented-on type's head ident;
    /// for `use`: the imported path text).
    pub name: String,
    /// Char offset of the item keyword.
    pub start: usize,
    /// Char offset one past the closing `}` or `;`.
    pub end: usize,
    /// Whether the item starts inside a `#[cfg(test)]`-erased region.
    pub in_test: bool,
}

const KEYWORDS: [(&str, ItemKind); 10] = [
    ("fn", ItemKind::Fn),
    ("struct", ItemKind::Struct),
    ("enum", ItemKind::Enum),
    ("trait", ItemKind::Trait),
    ("impl", ItemKind::Impl),
    ("mod", ItemKind::Mod),
    ("use", ItemKind::Use),
    ("const", ItemKind::Const),
    ("static", ItemKind::Static),
    ("type", ItemKind::TypeAlias),
];

fn in_regions(regions: &[(usize, usize)], off: usize) -> bool {
    regions.iter().any(|&(a, b)| a <= off && off < b)
}

/// Offset one past the item's terminator: the `}` matching its first
/// body brace, or its `;`. Brace groups before the terminator (e.g.
/// `const X: Foo = Foo { a: 1 };`) are skipped as units.
fn item_end(code: &[char], from: usize, brace_bodied: bool) -> usize {
    let n = code.len();
    let mut j = from;
    while j < n {
        match code[j] {
            '{' if brace_bodied => return match_brace(code, j),
            '{' => j = match_brace(code, j),
            ';' => return j + 1,
            _ => j += 1,
        }
    }
    n
}

/// Name of an `impl` block: skip optional generics after `impl`, then
/// take the head ident of the implemented type — the segment after
/// `for` when the block is a trait impl.
fn impl_name(code: &[char], mut j: usize) -> (usize, String) {
    let n = code.len();
    j = skip_ws(code, j);
    if j < n && code[j] == '<' {
        let mut depth = 0i64;
        while j < n {
            if code[j] == '<' {
                depth += 1;
            } else if code[j] == '>' {
                depth -= 1;
                if depth == 0 {
                    j += 1;
                    break;
                }
            }
            j += 1;
        }
        j = skip_ws(code, j);
    }
    let (mut k, mut name) = ident_at(code, j);
    // `impl Trait for Type` — the item is named after Type.
    loop {
        let w = skip_ws(code, k);
        if w < n && is_ascii_ident(code[w]) {
            let (k2, word) = ident_at(code, w);
            if word == "for" {
                let t = skip_ws(code, k2);
                let (k3, tyname) = ident_at(code, t);
                if !tyname.is_empty() {
                    name = tyname;
                    k = k3;
                }
                break;
            }
        }
        if w < n && (code[w] == ':' || code[w] == '<') {
            // Path segment or generic args; keep scanning for `for`.
            k = w + 1;
            continue;
        }
        break;
    }
    (k, name)
}

/// Parse every item in stripped, `#[cfg(test)]`-erased-aware code.
/// `test_regions` are the erased spans from [`crate::blank_cfg_test`]
/// run on an unerased copy — items are still parsed there, flagged
/// `in_test`, so the audit stage can index test fns without re-reading.
pub fn scan_items(code: &[char], test_regions: &[(usize, usize)]) -> Vec<Item> {
    let n = code.len();
    let mut out = Vec::new();
    let mut i = 0;
    while i < n {
        if !is_ascii_ident(code[i]) || (i > 0 && is_ascii_ident(code[i - 1])) {
            i += 1;
            continue;
        }
        let (j, word) = ident_at(code, i);
        let Some(&(_, kind)) = KEYWORDS.iter().find(|(k, _)| *k == word) else {
            i = j;
            continue;
        };
        match kind {
            ItemKind::Impl => {
                let (_, name) = impl_name(code, j);
                if !name.is_empty() {
                    let end = item_end(code, j, true);
                    out.push(Item { kind, name, start: i, end, in_test: in_regions(test_regions, i) });
                }
            }
            ItemKind::Use => {
                let end = item_end(code, j, false);
                let name: String =
                    code[skip_ws(code, j)..end.saturating_sub(1).max(j)].iter().collect();
                let name = name.trim().to_string();
                if !name.is_empty() {
                    out.push(Item { kind, name, start: i, end, in_test: in_regions(test_regions, i) });
                }
            }
            ItemKind::Const | ItemKind::Static => {
                // `const fn` belongs to the Fn arm; `*const T` and
                // `<const N: usize>` are type positions — a const/static
                // *item* always reads `const NAME :`.
                let k = skip_ws(code, j);
                let (after, name) = ident_at(code, k);
                let (after, name) = if name == "mut" {
                    let k2 = skip_ws(code, after);
                    ident_at(code, k2)
                } else {
                    (after, name)
                };
                let colon = skip_ws(code, after);
                if !name.is_empty() && name != "fn" && code.get(colon) == Some(&':') {
                    let end = item_end(code, after, false);
                    out.push(Item { kind, name, start: i, end, in_test: in_regions(test_regions, i) });
                }
            }
            _ => {
                // fn / struct / enum / trait / mod / type: keyword, ws,
                // name ident, body to `{...}` or `;`.
                let k = skip_ws(code, j);
                if k > j {
                    let (after, name) = ident_at(code, k);
                    if !name.is_empty() {
                        let end = item_end(code, after, true);
                        out.push(Item {
                            kind,
                            name,
                            start: i,
                            end,
                            in_test: in_regions(test_regions, i),
                        });
                    }
                }
            }
        }
        i = j;
    }
    out
}

/// Every call site `name(` in stripped code: `(char_offset_of_name,
/// name)`. Declarations (`fn name(`) and control-flow keywords are
/// excluded; method calls are included under their method name.
pub fn scan_calls(code: &[char]) -> Vec<(usize, String)> {
    const NOT_CALLS: [&str; 9] =
        ["fn", "if", "while", "match", "for", "loop", "return", "in", "move"];
    let n = code.len();
    let mut out = Vec::new();
    let mut i = 0;
    let mut prev_word = String::new();
    while i < n {
        if is_ascii_ident(code[i]) && (i == 0 || !is_ascii_ident(code[i - 1])) {
            let (j, word) = ident_at(code, i);
            let k = skip_ws(code, j);
            if code.get(k) == Some(&'(')
                && !NOT_CALLS.contains(&word.as_str())
                && prev_word != "fn"
                && !word.chars().next().is_some_and(|c| c.is_ascii_digit())
            {
                out.push((i, word.clone()));
            }
            prev_word = word;
            i = j;
            continue;
        }
        i += 1;
    }
    out
}

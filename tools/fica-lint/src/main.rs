//! CLI driver for the two-stage lint/audit pass.
//!
//! ```text
//! fica-lint [--root DIR] [--json] [--self]
//! ```
//!
//! With no flags: discover the workspace root (nearest ancestor whose
//! `Cargo.toml` declares `[workspace]` — so `cargo run -p fica-lint`
//! behaves identically from any directory), load the whole workspace
//! model and run all nine rules. `--root DIR` pins the root explicitly.
//! `--json` emits the machine-readable `fica.lint/v1` report (every
//! violation, waived ones flagged) instead of the text report (unwaived
//! only, `path:line: [rule] message`). `--self` lints the lint tool's
//! own sources under `no-panic` / `fail-closed` instead of auditing the
//! workspace.
//!
//! Exit status: 0 clean (no unwaived violations), 1 violations found,
//! 2 usage or I/O error.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

use fica_lint::audit::{audit, discover_root, render_json, render_text, Workspace};
use fica_lint::Violation;

struct Opts {
    root: Option<PathBuf>,
    json: bool,
    self_mode: bool,
}

fn parse_args(args: &[String]) -> Result<Opts, String> {
    let mut opts = Opts { root: None, json: false, self_mode: false };
    let mut i = 0;
    while i < args.len() {
        match args[i].as_str() {
            "--json" => opts.json = true,
            "--self" => opts.self_mode = true,
            "--root" => {
                i += 1;
                let dir = args
                    .get(i)
                    .ok_or_else(|| "--root needs a directory argument".to_string())?;
                opts.root = Some(PathBuf::from(dir));
            }
            other => {
                return Err(format!(
                    "unknown argument {other:?} (usage: fica-lint [--root DIR] [--json] [--self])"
                ))
            }
        }
        i += 1;
    }
    Ok(opts)
}

fn collect_rs_files(root: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(root)?.collect::<Result<Vec<_>, _>>()?;
    entries.sort_by_key(|e| e.path());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

/// Self-lint: the analyzer's own sources under no-panic / fail-closed.
fn self_report(root: &Path) -> Result<(Vec<Violation>, usize), String> {
    let src_root = root.join("tools/fica-lint/src");
    if !src_root.is_dir() {
        return Err(format!("{} not found — not the workspace root?", src_root.display()));
    }
    let mut files = Vec::new();
    collect_rs_files(&src_root, &mut files)
        .map_err(|e| format!("walking {}: {e}", src_root.display()))?;
    files.sort();
    let mut viol = Vec::new();
    for path in &files {
        let rel: String = path
            .strip_prefix(&src_root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        for mut v in fica_lint::lint_self_file(&rel, &src) {
            v.path = format!("tools/fica-lint/src/{rel}");
            viol.push(v);
        }
    }
    viol.sort();
    Ok((viol, files.len()))
}

fn run() -> Result<bool, String> {
    let args: Vec<String> = std::env::args().skip(1).collect();
    let opts = parse_args(&args)?;
    let root = match &opts.root {
        Some(dir) => dir.clone(),
        None => {
            let cwd = std::env::current_dir().map_err(|e| format!("getting cwd: {e}"))?;
            discover_root(&cwd).ok_or_else(|| {
                "no workspace root found (no ancestor Cargo.toml with [workspace]); pass --root"
                    .to_string()
            })?
        }
    };

    let (viol, files) = if opts.self_mode {
        self_report(&root)?
    } else {
        let ws = Workspace::load(&root)?;
        let n = ws.files.len();
        (audit(&ws), n)
    };
    let rendered =
        if opts.json { render_json(&viol, files) } else { render_text(&viol, files) };
    print!("{rendered}");
    Ok(viol.iter().all(|v| v.waived))
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::from(1),
        Err(e) => {
            eprintln!("fica-lint: error: {e}");
            ExitCode::from(2)
        }
    }
}

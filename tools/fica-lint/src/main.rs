//! CLI driver: walk a source root (default `rust/src`, the workspace
//! layout) and report every unwaived violation.
//!
//! Exit status 0 when clean, 1 when violations were found, 2 on I/O
//! problems. Output format is `path:line: [rule] message`, one per line
//! — greppable and editor-clickable.

use std::path::{Path, PathBuf};
use std::process::ExitCode;

fn collect_rs_files(root: &Path, out: &mut Vec<PathBuf>) -> std::io::Result<()> {
    let mut entries: Vec<_> = std::fs::read_dir(root)?.collect::<Result<Vec<_>, _>>()?;
    entries.sort_by_key(|e| e.path());
    for entry in entries {
        let path = entry.path();
        if path.is_dir() {
            collect_rs_files(&path, out)?;
        } else if path.extension().is_some_and(|e| e == "rs") {
            out.push(path);
        }
    }
    Ok(())
}

fn run() -> Result<bool, String> {
    let root = std::env::args().nth(1).unwrap_or_else(|| "rust/src".to_string());
    let root = PathBuf::from(root);
    if !root.is_dir() {
        return Err(format!(
            "lint root {} is not a directory (run from the workspace root, or pass the source root as the first argument)",
            root.display()
        ));
    }
    let mut files = Vec::new();
    collect_rs_files(&root, &mut files).map_err(|e| format!("walking {}: {e}", root.display()))?;
    files.sort();

    let mut total = 0usize;
    for path in &files {
        let rel: String = path
            .strip_prefix(&root)
            .unwrap_or(path)
            .components()
            .map(|c| c.as_os_str().to_string_lossy())
            .collect::<Vec<_>>()
            .join("/");
        let src = std::fs::read_to_string(path)
            .map_err(|e| format!("reading {}: {e}", path.display()))?;
        for v in fica_lint::lint_file(&rel, &src) {
            println!("{rel}:{}: [{}] {}", v.line, v.rule, v.msg);
            total += 1;
        }
    }
    if total > 0 {
        println!("fica-lint: {total} violation(s)");
        Ok(false)
    } else {
        println!("fica-lint: clean ({} files)", files.len());
        Ok(true)
    }
}

fn main() -> ExitCode {
    match run() {
        Ok(true) => ExitCode::SUCCESS,
        Ok(false) => ExitCode::from(1),
        Err(e) => {
            eprintln!("fica-lint: error: {e}");
            ExitCode::from(2)
        }
    }
}

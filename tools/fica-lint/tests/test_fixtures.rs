//! Fixture-driven integration tests: each rule fires exactly once on its
//! fixture, the clean fixture is clean, and waiver scoping (trailing,
//! standalone, match-arm, file-wide, malformed) behaves as documented.

use fica_lint::{lint_file, Violation};

fn fixture(name: &str) -> String {
    let path = format!("{}/tests/fixtures/{name}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"))
}

/// 1-based line of the first source line containing `needle`.
fn line_containing(src: &str, needle: &str) -> usize {
    src.lines()
        .position(|l| l.contains(needle))
        .unwrap_or_else(|| panic!("fixture drifted: no line contains {needle:?}"))
        + 1
}

fn lines_for(violations: &[Violation], rule: &str) -> Vec<usize> {
    violations.iter().filter(|v| v.rule == rule).map(|v| v.line).collect()
}

#[test]
fn no_panic_fires_exactly_once() {
    let src = fixture("r1_no_panic.rs");
    // R1 applies everywhere; pick a core-solver path.
    let v = lint_file("ica/fixture.rs", &src);
    assert_eq!(v.len(), 1, "expected exactly one violation, got {v:?}");
    assert_eq!(v[0].rule, "no-panic");
    assert_eq!(v[0].line, line_containing(&src, "v.unwrap()"));
}

#[test]
fn float_accum_fires_exactly_once_in_scoped_paths() {
    let src = fixture("r2_float_accum.rs");
    let v = lint_file("backend/fixture.rs", &src);
    assert_eq!(v.len(), 1, "expected exactly one violation, got {v:?}");
    assert_eq!(v[0].rule, "float-accum");
    // The raw += inside bad_mean, not the sanctioned fold_lanes copy.
    let bad_mean_start = line_containing(&src, "fn bad_mean");
    assert!(v[0].line > bad_mean_start, "fired at {} before bad_mean ({bad_mean_start})", v[0].line);
}

#[test]
fn float_accum_is_scoped_to_reduction_paths() {
    let src = fixture("r2_float_accum.rs");
    // Outside backend/, linalg/, data/stats.rs the rule does not apply.
    let v = lint_file("experiments/fixture.rs", &src);
    assert!(v.is_empty(), "float-accum leaked outside its path scope: {v:?}");
}

#[test]
fn nondeterminism_fires_exactly_once() {
    let src = fixture("r3_nondeterminism.rs");
    let v = lint_file("coordinator/fixture.rs", &src);
    assert_eq!(v.len(), 1, "expected exactly one violation, got {v:?}");
    assert_eq!(v[0].rule, "nondeterminism");
    assert_eq!(v[0].line, line_containing(&src, "pub type Cache"));
}

#[test]
fn nondeterminism_is_exempt_under_bench() {
    let src = fixture("r3_nondeterminism.rs");
    let v = lint_file("bench/fixture.rs", &src);
    assert!(v.is_empty(), "bench/ should be exempt from nondeterminism: {v:?}");
}

/// The observability layer reads the clock by design; its output never
/// feeds the numerics. The exemption must be path-exact — the same
/// fixture still fires everywhere else (pinned by
/// `nondeterminism_fires_exactly_once` above).
#[test]
fn nondeterminism_is_exempt_under_obs() {
    let src = fixture("r3_nondeterminism.rs");
    let v = lint_file("obs/fixture.rs", &src);
    assert!(v.is_empty(), "obs/ should be exempt from nondeterminism: {v:?}");
}

#[test]
fn fail_closed_fires_exactly_once() {
    let src = fixture("r4_fail_closed.rs");
    let v = lint_file("data/fixture.rs", &src);
    assert_eq!(v.len(), 1, "expected exactly one violation, got {v:?}");
    assert_eq!(v[0].rule, "fail-closed");
    assert_eq!(v[0].line, line_containing(&src, "pub fn decode_header"));
    assert!(v[0].msg.contains("decode_header"), "msg should name the fn: {}", v[0].msg);
}

#[test]
fn fail_closed_is_scoped_to_decoder_paths() {
    let src = fixture("r4_fail_closed.rs");
    let v = lint_file("ica/fixture.rs", &src);
    assert!(v.is_empty(), "fail-closed leaked outside data/ and util/json.rs: {v:?}");
}

#[test]
fn clean_file_is_clean() {
    let src = fixture("clean.rs");
    // Lint it under the strictest path scope: all four rules active.
    let v = lint_file("data/stats.rs", &src);
    assert!(v.is_empty(), "clean fixture reported violations: {v:?}");
}

#[test]
fn waiver_scoping() {
    let src = fixture("waiver_scoping.rs");
    let v = lint_file("ica/fixture.rs", &src);

    // Silenced: trailing waiver line, standalone-covered statement, waived
    // match arm. Firing: the expect after the standalone scope ends, plus
    // the two unwraps whose waivers are malformed.
    // The waiver with no justification text is the only line that *ends*
    // with the bare `allow(no-panic)`.
    let missing_justification = src
        .lines()
        .position(|l| l.trim_end().ends_with("allow(no-panic)"))
        .expect("fixture drifted: no bare allow(no-panic) line")
        + 1;
    let no_panic = lines_for(&v, "no-panic");
    assert_eq!(
        no_panic,
        vec![
            line_containing(&src, "w.expect"),
            missing_justification,
            line_containing(&src, "allow(no-panics)"),
        ],
        "unexpected no-panic lines: {v:?}"
    );

    // Both malformed waivers are themselves reported.
    let bad = lines_for(&v, "bad-waiver");
    assert_eq!(bad.len(), 2, "expected two bad-waiver reports: {v:?}");
    assert_eq!(v.len(), no_panic.len() + bad.len(), "unexpected extra rules: {v:?}");
}

#[test]
fn allow_file_silences_whole_file_for_its_rule_only() {
    let src = fixture("allow_file.rs");
    let v = lint_file("coordinator/fixture.rs", &src);
    assert!(v.is_empty(), "allow-file should silence both HashMaps: {v:?}");

    // The same file without its waiver line fires twice.
    let stripped: String = src
        .lines()
        .filter(|l| !l.contains("fica-lint:"))
        .map(|l| format!("{l}\n"))
        .collect();
    let v = lint_file("coordinator/fixture.rs", &stripped);
    assert_eq!(lines_for(&v, "nondeterminism").len(), 2, "expected both HashMaps to fire: {v:?}");
}

//! Integration tests for the item-graph (audit) stage: each of the
//! five PR 8 rules fires exactly once on its fixture, the clean demo
//! workspace audits clean, the drifted twin reports exactly the seeded
//! failures, JSON reports match the checked-in expected files byte for
//! byte (the same files CI diffs against `mirror.py`), and workspace
//! discovery resolves the nearest `[workspace]` manifest from any
//! subdirectory.

use fica_lint::audit::{audit, discover_root, render_json, Workspace};
use fica_lint::{lint_file, Violation};
use std::path::PathBuf;

fn fixture_path(name: &str) -> PathBuf {
    PathBuf::from(format!("{}/tests/fixtures/{name}", env!("CARGO_MANIFEST_DIR")))
}

fn fixture(name: &str) -> String {
    let path = fixture_path(name);
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {}: {e}", path.display()))
}

fn expected(name: &str) -> String {
    let path = format!("{}/tests/expected/{name}", env!("CARGO_MANIFEST_DIR"));
    std::fs::read_to_string(&path).unwrap_or_else(|e| panic!("read {path}: {e}"))
}

/// 1-based line of the first source line containing `needle`.
fn line_containing(src: &str, needle: &str) -> usize {
    src.lines()
        .position(|l| l.contains(needle))
        .unwrap_or_else(|| panic!("fixture drifted: no line contains {needle:?}"))
        + 1
}

fn ws(entries: &[(&str, &str)]) -> Workspace {
    let owned = entries.iter().map(|(p, s)| (p.to_string(), s.to_string())).collect();
    Workspace::from_entries(owned)
}

fn unwaived(v: Vec<Violation>) -> Vec<Violation> {
    v.into_iter().filter(|v| !v.waived).collect()
}

/// A header-only contract table: satisfies the anchor check while
/// contributing zero rows, so `contract-coverage` stays quiet.
const EMPTY_CONTRACTS: &str = "| paths compared | guarantee | why | pinned by |\n|---|---|---|---|\n";

#[test]
fn stale_waiver_fires_exactly_once() {
    let src = fixture("r5_stale_waiver.rs");
    let v = lint_file("ica/fixture.rs", &src);
    assert_eq!(v.len(), 1, "expected exactly one violation, got {v:?}");
    assert_eq!(v[0].rule, "stale-waiver");
    assert_eq!(v[0].line, line_containing(&src, "stale: the expect below"));
    assert!(v[0].msg.contains("no longer suppresses anything"), "msg: {}", v[0].msg);
}

#[test]
fn unchecked_arith_fires_exactly_once() {
    let src = fixture("r6_unchecked_arith.rs");
    let v = lint_file("data/fixture.rs", &src);
    assert_eq!(v.len(), 1, "expected exactly one violation, got {v:?}");
    assert_eq!(v[0].rule, "unchecked-arith");
    assert_eq!(v[0].line, line_containing(&src, "rows * cols"));
}

#[test]
fn unchecked_arith_is_scoped_to_size_handling_paths() {
    let src = fixture("r6_unchecked_arith.rs");
    // Outside data/ and util/json.rs the rule does not apply — and
    // data/stats.rs is carved out (it is float-accum territory).
    for rel in ["ica/fixture.rs", "data/stats.rs"] {
        let v = lint_file(rel, &src);
        assert!(v.is_empty(), "unchecked-arith leaked into {rel}: {v:?}");
    }
}

#[test]
fn lock_hygiene_fires_exactly_once_on_reversed_pair() {
    let src = fixture("r7_lock_hygiene.rs");
    let v = lint_file("coordinator/fixture.rs", &src);
    assert_eq!(v.len(), 1, "expected exactly one violation, got {v:?}");
    assert_eq!(v[0].rule, "lock-hygiene");
    assert_eq!(v[0].line, line_containing(&src, "let late = s.stats.lock()"));
    assert!(v[0].msg.contains("violates the declared lock-order"), "msg: {}", v[0].msg);
}

#[test]
fn lock_hygiene_is_scoped_to_concurrency_paths() {
    let src = fixture("r7_lock_hygiene.rs");
    let v = lint_file("ica/fixture.rs", &src);
    assert!(v.is_empty(), "lock-hygiene leaked outside its path scope: {v:?}");
}

#[test]
fn schema_drift_fires_exactly_once_on_undocumented_bump() {
    // The code bumped fica.demo to v2; docs still say v1.
    let v = unwaived(audit(&ws(&[
        ("rust/src/lib.rs", "pub const DEMO_SCHEMA: &str = \"fica.demo/v2\";\n"),
        ("docs/DEMO.md", "the tag is `fica.demo/v1`\n"),
        ("ARCHITECTURE.md", EMPTY_CONTRACTS),
    ])));
    assert_eq!(v.len(), 1, "expected exactly one violation, got {v:?}");
    assert_eq!(v[0].rule, "schema-drift");
    assert!(v[0].msg.contains("fica.demo/v2"), "msg: {}", v[0].msg);
    assert!(v[0].msg.contains("not documented"), "msg: {}", v[0].msg);
}

#[test]
fn contract_coverage_fires_exactly_once_on_deleted_test() {
    let arch = format!("{EMPTY_CONTRACTS}| `encode` roundtrip | bit-exact | why | `gone_test` |\n");
    let v = unwaived(audit(&ws(&[
        ("rust/src/lib.rs", "pub fn encode() {}\n"),
        ("rust/tests/test_demo.rs", "#[test]\nfn other_test() {\n    let _ = 1;\n}\n"),
        ("ARCHITECTURE.md", arch.as_str()),
    ])));
    assert_eq!(v.len(), 1, "expected exactly one violation, got {v:?}");
    assert_eq!(v[0].rule, "contract-coverage");
    assert!(v[0].msg.contains("`gone_test`"), "msg: {}", v[0].msg);
    assert!(v[0].msg.contains("no such test fn"), "msg: {}", v[0].msg);
}

#[test]
fn clean_demo_workspace_audits_clean() {
    let root = fixture_path("audit_ws");
    let ws = Workspace::load(&root).unwrap_or_else(|e| panic!("load audit_ws: {e}"));
    let v = audit(&ws);
    assert!(v.is_empty(), "clean workspace reported violations: {v:?}");
    assert_eq!(render_json(&v, ws.files.len()), expected("audit_ws.json"));
}

#[test]
fn drifted_workspace_reports_each_seeded_failure() {
    let root = fixture_path("audit_ws_drift");
    let ws = Workspace::load(&root).unwrap_or_else(|e| panic!("load audit_ws_drift: {e}"));
    let v = audit(&ws);
    assert_eq!(v.len(), 5, "expected the five seeded failures, got {v:?}");

    let has = |needle: &str| v.iter().any(|x| x.msg.contains(needle));
    // Seeded schema-tag drift: code writes v2, docs never followed.
    assert!(has("schema tag `fica.demo/v2` in code is not documented"), "{v:?}");
    // Schema-named const whose initializer lost its tag.
    assert!(has("const `AUX_SCHEMA` is schema-named"), "{v:?}");
    // Fixture carrying a version the code never wrote.
    assert!(has("fixture schema tag `fica.demo/v3` matches no code tag"), "{v:?}");
    // Removed contract test: the row's pin dangles.
    assert!(has("pins `demo_roundtrip` but no such test fn exists"), "{v:?}");
    // Row that never named a pinning test.
    assert!(has("pins no test"), "{v:?}");

    // The machine-readable report matches the checked-in expectation
    // byte for byte — the same file CI diffs against mirror.py.
    assert_eq!(render_json(&v, ws.files.len()), expected("audit_ws_drift.json"));
}

/// Regression (PR 8): rule scopes are pinned to the workspace root
/// discovered from the nearest `[workspace]` manifest, so running from
/// a subdirectory resolves the same root — here the fixture workspace,
/// not the enclosing repository (whose manifest is further up).
#[test]
fn discover_root_resolves_nearest_workspace_from_subdirectory() {
    let sub = fixture_path("audit_ws/rust/src");
    let found = discover_root(&sub).unwrap_or_else(|| panic!("no root found from {sub:?}"));
    assert_eq!(found, fixture_path("audit_ws"));
}

/// Acceptance gate: the repository's own workspace is lint-clean —
/// zero unwaived violations and zero stale waivers under all nine
/// rules. (`CARGO_MANIFEST_DIR` is `tools/fica-lint`; the repo root is
/// two levels up.)
#[test]
fn repository_workspace_is_lint_clean() {
    let root = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join("../..");
    let ws = Workspace::load(&root).unwrap_or_else(|e| panic!("load repo workspace: {e}"));
    let v = unwaived(audit(&ws));
    assert!(v.is_empty(), "repo workspace has unwaived violations: {v:#?}");
}

//! Fixture: the `no-panic` rule fires exactly once — on the `.unwrap()`
//! in `bad`. Everything else is a sanctioned alternative.

/// Fine: typed error path.
pub fn good(v: Option<u32>) -> Result<u32, String> {
    v.ok_or_else(|| "empty".to_string())
}

/// Fine: `debug_assert!` and the `_eq`/`_ne` assert family are allowed.
pub fn also_good(n: usize) {
    debug_assert!(n < usize::MAX);
    assert_eq!(n, n);
    assert_ne!(n, n + 1);
}

/// Fine: `unwrap_or_else` is not `unwrap`.
pub fn still_good(v: Option<u32>) -> u32 {
    v.unwrap_or_else(|| 0)
}

pub fn bad(v: Option<u32>) -> u32 {
    v.unwrap()
}

#[cfg(test)]
mod tests {
    #[test]
    fn panics_in_test_code_are_fine() {
        assert!(super::bad(Some(1)) == 1);
        let _ = Some(2).unwrap();
        panic!("test code is exempt");
    }
}

//! Fixture: a clean file. Typed errors, debug asserts, BTreeMap, and
//! string/comment text that would trip every rule if the scanner failed
//! to blank it: panic!("no"), x.unwrap(), HashMap, Instant, a += b.

use std::collections::BTreeMap;

pub fn lookup(map: &BTreeMap<String, f64>, key: &str) -> Result<f64, String> {
    map.get(key).copied().ok_or_else(|| format!("missing key {key}"))
}

pub fn clamp_positive(x: f64) -> f64 {
    debug_assert!(!x.is_nan());
    let decoy = "panic!(\"inside a string\") .unwrap() HashMap Instant";
    let raw_decoy = r#"assert!(also inside a string) SystemTime"#;
    let _ = (decoy, raw_decoy);
    x.max(0.0)
}

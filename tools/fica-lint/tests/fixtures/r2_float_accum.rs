//! Fixture: the `float-accum` rule fires exactly once — on the raw `+=`
//! in `bad_mean`. Sanctioned helpers and integer counters are exempt.

/// Sanctioned by name: accumulation order is pinned here.
pub(crate) fn fold_lanes(xs: &[f64]) -> f64 {
    let mut acc = 0.0;
    for &x in xs {
        acc += x;
    }
    acc
}

/// Sanctioned by name: `.sum()` inside a reduction helper is fine.
pub(crate) fn tree_reduce(xs: &[f64]) -> f64 {
    xs.iter().sum()
}

/// Integer-literal RHS: a counter, not a float reduction.
pub fn count(xs: &[f64]) -> usize {
    let mut n = 0;
    for x in xs {
        if x.is_finite() {
            n += 1;
        }
    }
    n
}

pub fn bad_mean(xs: &[f64]) -> f64 {
    let mut acc = 0.0;
    for &x in xs {
        acc += x;
    }
    acc / xs.len() as f64
}

//! Pins the demo contract row.

use fica_demo::encode_demo;

#[test]
fn demo_roundtrip() {
    let s = encode_demo(&[1, 2, 3]);
    assert_eq!(s, "fica.demo/v1 1 2 3");
}

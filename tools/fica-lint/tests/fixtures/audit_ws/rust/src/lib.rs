//! Demo crate for the fica-audit workspace fixtures.

/// Tag written at the head of every demo payload.
pub const DEMO_SCHEMA: &str = "fica.demo/v1";

/// Encode a demo payload: the schema tag, then the values.
pub fn encode_demo(values: &[u64]) -> String {
    let mut out = String::from(DEMO_SCHEMA);
    for v in values {
        out.push(' ');
        out.push_str(&v.to_string());
    }
    out
}

//! Fixture: the `stale-waiver` rule fires exactly once — on the waiver
//! whose underlying violation was refactored away. The live waiver
//! (still suppressing a real `no-panic` hit) stays silent.

/// Fine: this waiver still suppresses a live violation.
pub fn live(v: Option<usize>) -> usize {
    // fica-lint: allow(no-panic) — fixture: deliberately waived unwrap
    v.unwrap()
}

/// The expect this waiver used to cover became a fallback; the waiver
/// now suppresses nothing and must be deleted.
pub fn fixed(v: Option<usize>) -> usize {
    // fica-lint: allow(no-panic) — stale: the expect below became a checked fallback
    v.unwrap_or(0)
}

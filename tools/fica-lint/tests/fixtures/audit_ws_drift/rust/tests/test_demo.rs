//! The roundtrip test was deleted in a refactor; only an unrelated
//! check remains, so the contract row's pin dangles.

#[test]
fn unrelated_check() {
    assert_eq!(2 + 2, 4);
}

//! Demo crate, drifted: the schema tag moved to v2 without a docs
//! update, and `AUX_SCHEMA` carries no tag at all.

/// Tag written at the head of every demo payload.
pub const DEMO_SCHEMA: &str = "fica.demo/v2";

/// Schema-named, but its initializer embeds no tag.
pub const AUX_SCHEMA: u32 = 3;

/// Encode a demo payload: the schema tag, then the values.
pub fn encode_demo(values: &[u64]) -> String {
    let mut out = String::from(DEMO_SCHEMA);
    for v in values {
        out.push(' ');
        out.push_str(&v.to_string());
    }
    out
}

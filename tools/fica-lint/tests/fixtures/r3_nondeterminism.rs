//! Fixture: the `nondeterminism` rule fires exactly once — on the
//! `HashMap` type alias. A BTreeMap is the sanctioned container, and
//! mentions of Instant or SystemTime in comments are blanked before the
//! rules run.

use std::collections::BTreeMap;

/// Fine: deterministic iteration order.
pub fn ordered() -> BTreeMap<String, u32> {
    BTreeMap::new()
}

pub type Cache = std::collections::HashMap<u64, u64>;

//! Fixture: the `lock-hygiene` rule fires exactly once — on the nested
//! acquisition in `reversed` that contradicts the declared order. The
//! well-ordered pair in `ordered` stays silent.

// fica-lint: lock-order(stats, results)

use std::sync::Mutex;

/// Two locks with a declared acquisition order.
pub struct Shared {
    /// Acquired first.
    pub stats: Mutex<u64>,
    /// Acquired second.
    pub results: Mutex<u64>,
}

/// Fine: acquired in the declared order.
pub fn ordered(s: &Shared) -> u64 {
    let g1 = s.stats.lock();
    let g2 = s.results.lock();
    let total = g1.map(|a| *a).unwrap_or(0) + g2.map(|b| *b).unwrap_or(0);
    total
}

pub fn reversed(s: &Shared) -> u64 {
    let early = s.results.lock();
    let late = s.stats.lock();
    let total = late.map(|a| *a).unwrap_or(0) + early.map(|b| *b).unwrap_or(0);
    total
}

//! Fixture: waiver grammar and scoping. Silenced: the trailing waiver's
//! own line, the standalone waiver's next statement, and the waived
//! match arm. Still firing: the expect outside the standalone scope,
//! plus the two unwraps under malformed waivers (each of which also
//! reports `bad-waiver`).

/// Trailing waiver silences its own line only.
pub fn trailing(v: Option<u32>) -> u32 {
    v.unwrap() // fica-lint: allow(no-panic) — fixture: trailing waiver covers this line
}

/// Standalone waiver covers exactly the next statement.
pub fn standalone(v: Option<u32>, w: Option<u32>) -> u32 {
    // fica-lint: allow(no-panic) — fixture: standalone waiver covers the next statement
    let a = v.unwrap();
    let b = w.expect("fires: outside the waiver scope");
    a + b
}

pub enum Kind {
    A,
    B,
}

/// Standalone waiver above a match arm ends at the enclosing block close.
pub fn match_arm(k: Kind, v: Option<u32>) -> u32 {
    match k {
        // fica-lint: allow(no-panic) — fixture: waiver above a match arm
        Kind::A => v.unwrap(),
        Kind::B => 0,
    }
}

/// A waiver without a justification is itself a violation.
pub fn missing_justification(v: Option<u32>) -> u32 {
    v.unwrap() // fica-lint: allow(no-panic)
}

/// A waiver naming an unknown rule is itself a violation.
pub fn unknown_rule(v: Option<u32>) -> u32 {
    v.unwrap() // fica-lint: allow(no-panics) — typo'd rule name does not silence
}

//! Fixture: the `unchecked-arith` rule fires exactly once — on the
//! size-marked multiply in `frame_bytes`. Checked arithmetic, float
//! math, and mixed `+` with an unmarked operand are not flagged.

/// Fine: checked multiply is the sanctioned form.
pub fn checked(rows: usize, cols: usize) -> Option<usize> {
    rows.checked_mul(cols)
}

/// Fine: float arithmetic is out of scope.
pub fn scale(x: f64) -> f64 {
    x * 8.0
}

/// Fine: `+` only fires when BOTH operands are size-marked.
pub fn shift(off: usize) -> usize {
    off + 1
}

pub fn frame_bytes(rows: usize, cols: usize) -> usize {
    rows * cols
}

//! Fixture: the `fail-closed` rule fires exactly once — on the
//! Result-less `decode_header`. Decoder-shaped names must return
//! `Result`; non-decoder names are not checked.

pub struct Header {
    pub rows: usize,
}

/// Fine: decoder returning Result.
pub fn parse_header(bytes: &[u8]) -> Result<Header, String> {
    if bytes.len() < 8 {
        return Err("short header".to_string());
    }
    Ok(Header { rows: bytes.len() })
}

/// Fine: not decoder-named, plain return is allowed.
pub fn rows_hint(h: &Header) -> usize {
    h.rows
}

pub fn decode_header(bytes: &[u8]) -> Header {
    Header { rows: bytes.len() }
}

//! Fixture: a file-wide waiver silences every occurrence of its rule,
//! and only its rule.

// fica-lint: allow-file(nondeterminism) — fixture: lookup-only caches, never iterated

pub type Cache = std::collections::HashMap<u64, u64>;
pub type OtherCache = std::collections::HashMap<u64, Vec<u64>>;

//! End-to-end driver: the full three-layer system on a real workload.
//!
//!     cargo run --release --example end_to_end
//!
//! Exercises every layer: synthetic experiment-A data (paper §3.2) →
//! whitening → the paper's six algorithms, with the full-batch methods
//! running on the **XLA backend** (AOT-compiled JAX/Pallas artifacts via
//! PJRT — Python is not running) and the stochastic baseline on the
//! native backend. Reports the paper's headline metric: time and
//! iterations to a gradient tolerance, per algorithm, plus the speedup of
//! preconditioned L-BFGS over the baselines. The run is recorded in
//! EXPERIMENTS.md.

use faster_ica::backend::{ComputeBackend, NativeBackend};
use faster_ica::ica::{solve, Algorithm, SolveResult, SolverConfig};
use faster_ica::linalg::Mat;
use faster_ica::preprocessing::{preprocess, Whitener};
use faster_ica::runtime::{default_artifact_dir, Engine, XlaBackend};
use faster_ica::signal;
use std::rc::Rc;

const TOL_SUMMARY: f64 = 1e-6;

fn main() -> anyhow::Result<()> {
    // Paper-size experiment A: N=40 Laplace sources, T=10000.
    let (n, t, seed) = (40, 10_000, 0);
    println!("=== end-to-end: experiment A (N={n}, T={t}) ===");
    let data = signal::experiment_a(n, t, seed);
    let pre = preprocess(&data.x, Whitener::Sphering);

    let engine = Rc::new(Engine::new(default_artifact_dir())?);
    println!(
        "PJRT: {} | artifacts registered: {}",
        engine.client().platform_name(),
        engine.registry().len()
    );

    let suite = ["gd", "infomax", "qn-h1", "lbfgs", "plbfgs-h1", "plbfgs-h2"];
    let mut rows = Vec::new();
    for id in suite {
        let algo = Algorithm::from_id(id).unwrap();
        let cfg = SolverConfig::new(algo).with_tol(1e-8).with_max_iters(200);
        let w0 = Mat::eye(n);
        // Full-batch methods go through the AOT artifacts; Infomax's
        // varying mini-batch shapes run on the native twin (DESIGN.md §7).
        let res: SolveResult = if id == "infomax" {
            let mut be = NativeBackend::new(pre.x.clone());
            solve(&mut be, &w0, &cfg)
        } else {
            let mut be = XlaBackend::new(engine.clone(), pre.x.clone())?;
            let r = solve(&mut be, &w0, &cfg);
            assert_eq!(be.name(), "xla");
            r
        };
        let last = res.trace.last().unwrap();
        println!(
            "{:>10}: iters→{:.0e} = {:>4}   time→{:.0e} = {:>9}   final |G|inf = {:.2e}",
            id,
            TOL_SUMMARY,
            res.trace
                .iters_to_tol(TOL_SUMMARY)
                .map(|v| v.to_string())
                .unwrap_or_else(|| "—".into()),
            TOL_SUMMARY,
            res.trace
                .time_to_tol(TOL_SUMMARY)
                .map(faster_ica::bench::fmt_duration)
                .unwrap_or_else(|| "—".into()),
            last.grad_inf
        );
        rows.push((id, res));
    }

    // Headline: preconditioned L-BFGS / quasi-Newton versus baselines.
    let time_of = |id: &str| {
        rows.iter()
            .find(|(i, _)| *i == id)
            .and_then(|(_, r)| r.trace.time_to_tol(TOL_SUMMARY))
    };
    let plbfgs = time_of("plbfgs-h2");
    let qn = time_of("qn-h1");
    let gd = time_of("gd");
    let fastest = match (plbfgs, qn) {
        (Some(a), Some(b)) => Some(a.min(b)),
        (a, b) => a.or(b),
    };
    if let (Some(fast), Some(slow)) = (fastest, gd) {
        println!(
            "\nspeedup of Hessian-informed methods over oracle-LS gradient descent: {:.0}x",
            slow / fast
        );
    }
    let info_final = rows
        .iter()
        .find(|(i, _)| *i == "infomax")
        .and_then(|(_, r)| r.trace.last().map(|rec| rec.grad_inf))
        .unwrap_or(f64::NAN);
    println!(
        "Infomax plateau after {} passes: |G|inf = {info_final:.2e} (paper: stalls ≥ 1e-3-ish)",
        rows.iter().find(|(i, _)| *i == "infomax").map(|(_, r)| r.iters).unwrap_or(0)
    );

    // The paper's qualitative claims, asserted:
    let conv = |id: &str| rows.iter().find(|(i, _)| *i == id).unwrap().1.converged;
    anyhow::ensure!(conv("plbfgs-h2"), "plbfgs-h2 must converge to 1e-8");
    anyhow::ensure!(conv("qn-h1"), "qn-h1 must converge on model-true data");
    anyhow::ensure!(!conv("infomax"), "infomax must plateau, not converge to 1e-8");
    println!("end-to-end OK");
    Ok(())
}

//! End-to-end driver: the full three-layer system on a real workload.
//!
//!     cargo run --release --example end_to_end
//!
//! Exercises every layer through the estimator front door: synthetic
//! experiment-A data (paper §3.2) → `Picard::fit` (centering, whitening,
//! solver) for each of the paper's six algorithms. With
//! `BackendChoice::Auto` the full-batch methods run on the **XLA
//! backend** (AOT-compiled JAX/Pallas artifacts via PJRT — Python is not
//! running) when artifacts are available, and on the native backend
//! otherwise. Reports the paper's headline metric: time and iterations
//! to a gradient tolerance, per algorithm, plus the speedup of
//! preconditioned L-BFGS over the baselines. The run is recorded in
//! EXPERIMENTS.md.

use faster_ica::estimator::{BackendChoice, IcaModel, Picard};
use faster_ica::ica::Algorithm;
use faster_ica::runtime::{default_artifact_dir, Engine};
use faster_ica::signal;
use faster_ica::IcaError;
use std::rc::Rc;

const TOL_SUMMARY: f64 = 1e-6;

fn main() -> Result<(), IcaError> {
    // Paper-size experiment A: N=40 Laplace sources, T=10000.
    let (n, t, seed) = (40, 10_000, 0);
    println!("=== end-to-end: experiment A (N={n}, T={t}) ===");
    let data = signal::experiment_a(n, t, seed);

    // One engine for the whole suite, so compiled artifacts are reused
    // across fits (None when PJRT is unavailable: Auto goes native).
    let shared_engine = Engine::new(default_artifact_dir()).ok().map(Rc::new);

    let suite = ["gd", "infomax", "qn-h1", "lbfgs", "plbfgs-h1", "plbfgs-h2"];
    let mut rows: Vec<(&str, IcaModel)> = Vec::new();
    for id in suite {
        let algo = Algorithm::from_id(id).expect("suite id");
        // Infomax's varying mini-batch shapes always run on the native
        // twin (DESIGN.md §7); Auto routes the rest through PJRT when
        // the artifacts exist.
        let backend =
            if id == "infomax" { BackendChoice::Native } else { BackendChoice::Auto };
        let mut picard = Picard::new()
            .algorithm(algo)
            .backend(backend)
            .tol(1e-8)
            .max_iters(200);
        if let Some(engine) = &shared_engine {
            picard = picard.engine(engine.clone());
        }
        let model = picard.fit(&data.x)?;
        let info = model.fit_info();
        println!(
            "{:>10} [{:>6}]: iters→{:.0e} = {:>4}   time→{:.0e} = {:>9}   final |G|inf = {:.2e}",
            id,
            info.backend,
            TOL_SUMMARY,
            info.trace
                .iters_to_tol(TOL_SUMMARY)
                .map(|v| v.to_string())
                .unwrap_or_else(|| "—".into()),
            TOL_SUMMARY,
            info.trace
                .time_to_tol(TOL_SUMMARY)
                .map(faster_ica::bench::fmt_duration)
                .unwrap_or_else(|| "—".into()),
            info.final_grad_inf
        );
        rows.push((id, model));
    }

    // Headline: preconditioned L-BFGS / quasi-Newton versus baselines.
    let time_of = |id: &str| {
        rows.iter()
            .find(|(i, _)| *i == id)
            .and_then(|(_, m)| m.fit_info().trace.time_to_tol(TOL_SUMMARY))
    };
    let plbfgs = time_of("plbfgs-h2");
    let qn = time_of("qn-h1");
    let gd = time_of("gd");
    let fastest = match (plbfgs, qn) {
        (Some(a), Some(b)) => Some(a.min(b)),
        (a, b) => a.or(b),
    };
    if let (Some(fast), Some(slow)) = (fastest, gd) {
        println!(
            "\nspeedup of Hessian-informed methods over oracle-LS gradient descent: {:.0}x",
            slow / fast
        );
    }
    let infomax = rows.iter().find(|(i, _)| *i == "infomax").map(|(_, m)| m.fit_info());
    println!(
        "Infomax plateau after {} passes: |G|inf = {:.2e} (paper: stalls ≥ 1e-3-ish)",
        infomax.map(|i| i.iters).unwrap_or(0),
        infomax.map(|i| i.final_grad_inf).unwrap_or(f64::NAN)
    );

    // The paper's qualitative claims, asserted:
    let conv = |id: &str| rows.iter().find(|(i, _)| *i == id).unwrap().1.fit_info().converged;
    assert!(conv("plbfgs-h2"), "plbfgs-h2 must converge to 1e-8");
    assert!(conv("qn-h1"), "qn-h1 must converge on model-true data");
    assert!(!conv("infomax"), "infomax must plateau, not converge to 1e-8");
    println!("end-to-end OK");
    Ok(())
}

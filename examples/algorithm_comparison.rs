//! Algorithm comparison on one dataset — a compact Fig. 2 panel.
//!
//!     cargo run --release --example algorithm_comparison [scale]
//!
//! Fits the paper's six algorithms through the `Picard` estimator on
//! experiment C (near-Gaussian mixtures — the hard case where the
//! elementary quasi-Newton loses its quadratic rate and preconditioned
//! L-BFGS shines) and prints the convergence table plus a terminal
//! log-log sparkline per algorithm.

use faster_ica::estimator::Picard;
use faster_ica::ica::{Algorithm, Trace};
use faster_ica::signal;

fn sparkline(trace: &Trace, cols: usize) -> String {
    const BARS: &[char] = &['▁', '▂', '▃', '▄', '▅', '▆', '▇', '█'];
    let max_iter = trace.last().map(|r| r.iter).unwrap_or(0);
    (0..cols)
        .map(|c| {
            let it = max_iter * c / cols.max(1);
            let g = trace.grad_at_iter(it).unwrap_or(f64::NAN).max(1e-12);
            // Map log10 in [-9, 0] onto the bar heights.
            let z = ((g.log10() + 9.0) / 9.0).clamp(0.0, 1.0);
            BARS[(z * (BARS.len() - 1) as f64).round() as usize]
        })
        .collect()
}

fn main() {
    let scale: f64 = std::env::args().nth(1).and_then(|s| s.parse().ok()).unwrap_or(0.5);
    let n = ((40.0 * scale) as usize).max(8);
    let t = ((5000.0 * scale) as usize).max(1000);
    println!("experiment C at N={n}, T={t} (α ramps 0.5→1, σ=0.1)\n");
    let data = signal::experiment_c(n, t, 1);

    println!(
        "{:>10} {:>7} {:>12} {:>12}   convergence (log |G|inf, left→right = iterations)",
        "algorithm", "iters", "final |G|", "time"
    );
    for id in Algorithm::paper_suite() {
        let algo = Algorithm::from_id(id).expect("suite id");
        let model = Picard::new()
            .algorithm(algo)
            .tol(1e-8)
            .max_iters(150)
            .fit(&data.x)
            .expect("fit");
        let info = model.fit_info();
        let last_time = info.trace.last().map(|r| r.time).unwrap_or(f64::NAN);
        println!(
            "{:>10} {:>7} {:>12.2e} {:>12}   {}",
            id,
            info.iters,
            info.final_grad_inf,
            faster_ica::bench::fmt_duration(last_time),
            sparkline(&info.trace, 40)
        );
    }
    println!("\npaper shape: solid (preconditioned) methods reach 1e-8; infomax plateaus;");
    println!("elementary qn loses its quadratic rate here but still beats gd.");
}

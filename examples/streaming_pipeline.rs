//! Streaming data plane end-to-end: write a recording to the `FICA1`
//! binary format, ingest it back in column chunks, and fit with the
//! sharded multithreaded backend.
//!
//!     cargo run --release --example streaming_pipeline
//!
//! This is the large-recording workflow: the raw matrix is never fully
//! materialized on the ingest side (the whitener comes from one-pass
//! streaming moments), and the Θ(N²T) solver sweeps are split across a
//! worker-thread pool.

use faster_ica::data::{write_bin, BinSource};
use faster_ica::estimator::{BackendChoice, Picard};
use faster_ica::ica::amari_distance;
use faster_ica::linalg::matmul;
use faster_ica::signal;
use std::time::Instant;

fn main() {
    // 1. A medium recording: 8 Laplace sources, 20k samples, random mix.
    let data = signal::experiment_a(8, 20_000, 3);
    let path = std::env::temp_dir().join("fica_streaming_demo.bin");
    write_bin(&path, &data.x).expect("write FICA1 file");
    println!(
        "wrote {} x {} recording to {} ({} bytes)",
        data.x.rows(),
        data.x.cols(),
        path.display(),
        24 + 8 * data.x.rows() * data.x.cols()
    );

    // 2. Stream it back and fit: chunked ingestion + sharded sweeps
    //    (workers = 0 means one per available core).
    let mut source = BinSource::open(&path).expect("open FICA1 file");
    let t0 = Instant::now();
    let model = Picard::new()
        .backend(BackendChoice::Sharded { workers: 0 })
        .chunk_cols(4096)
        .tol(1e-8)
        .max_iters(200)
        .fit_source(&mut source)
        .expect("fit from file");
    let elapsed = t0.elapsed().as_secs_f64();

    let info = model.fit_info();
    println!(
        "backend {} | converged = {} in {} iterations ({elapsed:.3}s wall)",
        info.backend, info.converged, info.iters
    );

    // 3. Same quality bar as the in-memory path: W·A is a scaled
    //    permutation when the sources are recovered.
    let perm = matmul(&model.unmixing_matrix(), &data.mixing);
    let d = amari_distance(&perm);
    println!("Amari distance to a perfect separation: {d:.2e}");
    assert!(info.converged && d < 0.1);

    // 4. Out-of-core: the whitened matrix is parked in a FICA1 scratch
    //    file and re-streamed per iteration — peak resident data for the
    //    recording is O(N·chunk·workers), so T is bounded by disk.
    let mut source = BinSource::open(&path).expect("open FICA1 file");
    let t0 = Instant::now();
    let ooc = Picard::new()
        .out_of_core(true)
        .backend(BackendChoice::Sharded { workers: 0 })
        .chunk_cols(4096)
        .tol(1e-8)
        .max_iters(200)
        .fit_source(&mut source)
        .expect("out-of-core fit");
    let elapsed = t0.elapsed().as_secs_f64();
    println!(
        "backend {} | converged = {} in {} iterations ({elapsed:.3}s wall)",
        ooc.fit_info().backend,
        ooc.fit_info().converged,
        ooc.fit_info().iters
    );
    let d_ooc = ooc.w().max_abs_diff(model.w());
    println!("out-of-core vs in-memory |ΔW|max = {d_ooc:.2e}");
    assert!(ooc.fit_info().converged && d_ooc < 1e-6);
    println!("streaming pipeline OK");
}

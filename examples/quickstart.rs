//! Quickstart: separate a mixture of Laplace sources in ~20 lines.
//!
//!     cargo run --release --example quickstart
//!
//! The `Picard` builder is the front door: it centers, whitens, solves,
//! and returns a fitted `IcaModel`. With `BackendChoice::Auto` the fit
//! uses the AOT-compiled JAX/Pallas artifacts through PJRT when they are
//! available, falling back to the native backend.

use faster_ica::estimator::{BackendChoice, Picard};
use faster_ica::ica::amari_distance;
use faster_ica::linalg::matmul;
use faster_ica::signal;

fn main() {
    // 1. Make a toy problem: 4 Laplace sources, 2000 samples, random mix.
    let data = signal::experiment_a(4, 2000, /*seed=*/ 7);
    println!("mixed {} sources x {} samples", data.x.rows(), data.x.cols());

    // 2. Fit with the paper's preconditioned L-BFGS (H2) — the default.
    let model = Picard::new()
        .backend(BackendChoice::Auto)
        .tol(1e-9)
        .max_iters(100)
        .fit(&data.x)
        .expect("fit");

    let info = model.fit_info();
    match &info.backend_fallback {
        Some(why) => println!("backend: {} ({why})", info.backend),
        None => println!("backend: {}", info.backend),
    }
    println!(
        "converged = {} in {} iterations, final |G|inf = {:.2e}",
        info.converged, info.iters, info.final_grad_inf
    );

    // 3. Extract sources and check the recovery: the effective unmixing
    //    composed with the true mixing should be a scaled permutation.
    let sources = model.transform(&data.x).expect("transform");
    assert_eq!(sources.rows(), 4);
    let perm = matmul(&model.unmixing_matrix(), &data.mixing);
    println!("Amari distance to a perfect separation: {:.2e}", amari_distance(&perm));
    assert!(info.converged && amari_distance(&perm) < 0.1);

    // 4. The fitted model serializes losslessly.
    let json = model.to_json_string().expect("serialize");
    let reloaded = faster_ica::estimator::IcaModel::from_json_str(&json).expect("load");
    let again = reloaded.transform(&data.x).expect("transform");
    assert!(again.max_abs_diff(&sources) == 0.0);
    println!("quickstart OK");
}

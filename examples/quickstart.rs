//! Quickstart: separate a mixture of Laplace sources in ~20 lines.
//!
//!     cargo run --release --example quickstart
//!
//! Uses the AOT-compiled JAX/Pallas artifacts through PJRT when they are
//! available (`make artifacts`), falling back to the native backend.

use faster_ica::backend::NativeBackend;
use faster_ica::ica::{amari_distance, solve, Algorithm, HessianApprox, SolverConfig};
use faster_ica::linalg::{matmul, Mat};
use faster_ica::preprocessing::{preprocess, Whitener};
use faster_ica::runtime::{default_artifact_dir, Engine, XlaBackend};
use faster_ica::signal;
use std::rc::Rc;

fn main() {
    // 1. Make a toy problem: 4 Laplace sources, 2000 samples, random mix.
    let data = signal::experiment_a(4, 2000, /*seed=*/ 7);
    println!("mixed {} sources x {} samples", data.x.rows(), data.x.cols());

    // 2. Standard preprocessing: center + whiten.
    let pre = preprocess(&data.x, Whitener::Sphering);

    // 3. Fit with the paper's preconditioned L-BFGS (H2 approximation).
    let algo = Algorithm::Lbfgs { precond: Some(HessianApprox::H2), memory: 7 };
    let cfg = SolverConfig::new(algo).with_tol(1e-9).with_max_iters(100);
    let w0 = Mat::eye(4);

    let result = match Engine::new(default_artifact_dir())
        .map(Rc::new)
        .and_then(|e| XlaBackend::new(e, pre.x.clone()))
    {
        Ok(mut xla) => {
            println!("backend: xla (AOT JAX/Pallas artifacts via PJRT)");
            solve(&mut xla, &w0, &cfg)
        }
        Err(why) => {
            println!("backend: native ({why})");
            solve(&mut NativeBackend::new(pre.x.clone()), &w0, &cfg)
        }
    };

    // 4. Check the recovery: W·K·A should be a scaled permutation.
    println!(
        "converged = {} in {} iterations, final |G|inf = {:.2e}",
        result.converged,
        result.iters,
        result.trace.last().map(|r| r.grad_inf).unwrap_or(f64::NAN),
    );
    let unmix = matmul(&result.w, &pre.k);
    let perm = matmul(&unmix, &data.mixing);
    println!("Amari distance to a perfect separation: {:.2e}", amari_distance(&perm));
    assert!(result.converged && amari_distance(&perm) < 0.1);
    println!("quickstart OK");
}

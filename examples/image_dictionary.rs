//! Patch-ICA dictionary learning (paper §3.4's workload).
//!
//!     cargo run --release --example image_dictionary
//!
//! Extracts 8x8 patches from dead-leaves images, fits a `Picard` model,
//! and inspects the learned dictionary (`model.mixing_matrix()` columns
//! = features): ICA on natural-image statistics learns localized
//! edge-like atoms, which show up as strongly *sparse* (high kurtosis)
//! source activations and spatially structured atoms.

use faster_ica::estimator::Picard;
use faster_ica::linalg::Mat;
use faster_ica::signal::images::patch_dataset;

fn main() {
    let s = 8;
    let x = patch_dataset(/*images=*/ 20, /*hw=*/ 64, s, /*patches=*/ 8000, /*seed=*/ 5);
    println!("patches: {} x {}", x.rows(), x.cols());

    let model = Picard::new().tol(1e-6).max_iters(300).fit(&x).expect("fit");
    let info = model.fit_info();
    println!(
        "ICA: {} iterations, final |G|inf = {:.2e}",
        info.iters, info.final_grad_inf
    );

    // Dictionary atoms = columns of the mixing matrix (W·K)⁻¹.
    let atoms = model.mixing_matrix().expect("unmixing invertible");

    // Activation sparsity: source kurtosis should be super-Gaussian.
    let y = model.transform(&x).expect("transform");
    let mut kurts: Vec<f64> = (0..y.rows())
        .map(|i| {
            let r = y.row(i);
            let n = r.len() as f64;
            let m = r.iter().sum::<f64>() / n;
            let v = r.iter().map(|x| (x - m).powi(2)).sum::<f64>() / n;
            r.iter().map(|x| (x - m).powi(4)).sum::<f64>() / n / (v * v) - 3.0
        })
        .collect();
    kurts.sort_by(|a, b| b.partial_cmp(a).unwrap());
    let median_kurt = kurts[kurts.len() / 2];
    println!("median activation kurtosis: {median_kurt:.2} (must be > 0: sparse code)");
    assert!(median_kurt > 0.5, "activations not sparse: {median_kurt}");

    // Spatial structure: a localized edge atom concentrates its energy in
    // few pixels; compare against the dense white-noise baseline 1/d.
    let d = s * s;
    let participation = |col: usize| -> f64 {
        // Inverse participation ratio in [1/d, 1]: higher = localized.
        let mut p2 = 0.0;
        let mut p4 = 0.0;
        for rix in 0..d {
            let v = atoms[(rix, col)];
            p2 += v * v;
            p4 += v * v * v * v;
        }
        p4 / (p2 * p2)
    };
    let mut iprs: Vec<f64> = (0..d).map(participation).collect();
    iprs.sort_by(|a, b| b.partial_cmp(a).unwrap());
    println!(
        "atom localization (IPR): max = {:.4}, median = {:.4}, white-noise level = {:.4}",
        iprs[0],
        iprs[d / 2],
        2.0 / d as f64 // ≈ E[IPR] for a Gaussian random vector ~ 3/(d+2)
    );
    assert!(iprs[d / 2] > 2.0 / d as f64, "atoms are unstructured noise");

    // Render the most localized atom as ASCII.
    let best = (0..d)
        .max_by(|&a, &b| participation(a).partial_cmp(&participation(b)).unwrap())
        .unwrap();
    let mut shade = Mat::zeros(s, s);
    let mut mx = 0.0f64;
    for r in 0..d {
        mx = mx.max(atoms[(r, best)].abs());
    }
    for r in 0..d {
        shade[(r / s, r % s)] = 0.5 + 0.5 * atoms[(r, best)] / mx;
    }
    println!("most localized atom (column {best}):");
    println!("{}", faster_ica::experiments::report::ascii_matrix(&shade));
    println!("image_dictionary OK");
}

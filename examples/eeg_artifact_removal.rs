//! EEG artifact removal — the paper's motivating neuroscience workflow.
//!
//!     cargo run --release --example eeg_artifact_removal
//!
//! Generates a synthetic EEG recording (cortical rhythms + eye blinks +
//! muscle bursts + line hum, mixed through a smooth leadfield), fits a
//! `Picard` model, identifies artifact components by kurtosis (blinks
//! are extremely super-Gaussian), zeroes them, and reconstructs cleaned
//! channels with `inverse_transform` — reporting how much blink energy
//! was removed while preserving the background activity.

use faster_ica::estimator::Picard;
use faster_ica::linalg::Mat;
use faster_ica::signal::eeg_sim::{generate, EegConfig};

fn kurtosis(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let m = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / n;
    xs.iter().map(|x| (x - m).powi(4)).sum::<f64>() / n / (var * var) - 3.0
}

fn main() {
    let cfg = EegConfig { channels: 24, samples: 20_000, ..Default::default() };
    let x = generate(&cfg, 11);
    println!("synthetic EEG: {} channels x {} samples", x.rows(), x.cols());

    let model = Picard::new().tol(1e-7).max_iters(200).fit(&x).expect("fit");
    let info = model.fit_info();
    println!(
        "ICA: {} iterations, final |G|inf = {:.2e}",
        info.iters, info.final_grad_inf
    );

    // Sources straight from the fitted model.
    let y = model.transform(&x).expect("transform");
    let n = y.rows();
    let mut kurt: Vec<(usize, f64)> = (0..n).map(|i| (i, kurtosis(y.row(i)))).collect();
    kurt.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("top component kurtoses (blinks/artifacts are heavy-tailed):");
    for (i, k) in kurt.iter().take(5) {
        println!("  component {i:>3}: kurtosis {k:>8.2}");
    }

    // Zero every component with kurtosis > 5 (blink-like transients).
    let artifacts: Vec<usize> =
        kurt.iter().filter(|(_, k)| *k > 5.0).map(|(i, _)| *i).collect();
    println!("removing {} artifact component(s): {artifacts:?}", artifacts.len());
    assert!(!artifacts.is_empty(), "simulator always injects blinks");

    let mut y_clean = y.clone();
    for &i in &artifacts {
        y_clean.row_mut(i).fill(0.0);
    }
    // Back to channel space: the model inverts W, K and restores means.
    let x_clean = model.inverse_transform(&y_clean).expect("inverse_transform");

    // Report per-channel energy removed, comparing centered signals so
    // the DC offsets the model restores do not skew the ratio.
    let centered = |m: &Mat| -> Mat {
        let mut c = m.clone();
        for i in 0..c.rows() {
            let mu = model.row_means()[i];
            for v in c.row_mut(i) {
                *v -= mu;
            }
        }
        c
    };
    let energy = |m: &Mat| -> f64 { m.as_slice().iter().map(|v| v * v).sum::<f64>() };
    let removed = 1.0 - energy(&centered(&x_clean)) / energy(&centered(&x));
    println!("fraction of total signal energy removed: {:.1}%", removed * 100.0);
    assert!(removed > 0.005 && removed < 0.9, "implausible removal {removed}");

    // The retained sources should be untouched (linearity check).
    let y_back = model.transform(&x_clean).expect("transform");
    let mut max_err = 0.0f64;
    for i in 0..n {
        if !artifacts.contains(&i) {
            for (a, b) in y_back.row(i).iter().zip(y_clean.row(i)) {
                max_err = max_err.max((a - b).abs());
            }
        }
    }
    println!("retained-component roundtrip error: {max_err:.2e}");
    assert!(max_err < 1e-8);
    println!("eeg_artifact_removal OK");
}

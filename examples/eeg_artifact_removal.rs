//! EEG artifact removal — the paper's motivating neuroscience workflow.
//!
//!     cargo run --release --example eeg_artifact_removal
//!
//! Generates a synthetic EEG recording (cortical rhythms + eye blinks +
//! muscle bursts + line hum, mixed through a smooth leadfield), unmixes
//! it with preconditioned L-BFGS, identifies artifact components by
//! kurtosis (blinks are extremely super-Gaussian), zeroes them, and
//! reconstructs cleaned channels — reporting how much blink energy was
//! removed while preserving the background activity.

use faster_ica::backend::NativeBackend;
use faster_ica::ica::{solve, Algorithm, HessianApprox, SolverConfig};
use faster_ica::linalg::{matmul, Lu, Mat};
use faster_ica::preprocessing::{preprocess, Whitener};
use faster_ica::signal::eeg_sim::{generate, EegConfig};

fn kurtosis(xs: &[f64]) -> f64 {
    let n = xs.len() as f64;
    let m = xs.iter().sum::<f64>() / n;
    let var = xs.iter().map(|x| (x - m).powi(2)).sum::<f64>() / n;
    xs.iter().map(|x| (x - m).powi(4)).sum::<f64>() / n / (var * var) - 3.0
}

fn main() {
    let cfg = EegConfig { channels: 24, samples: 20_000, ..Default::default() };
    let x = generate(&cfg, 11);
    println!("synthetic EEG: {} channels x {} samples", x.rows(), x.cols());

    let pre = preprocess(&x, Whitener::Sphering);
    let algo = Algorithm::Lbfgs { precond: Some(HessianApprox::H2), memory: 7 };
    let scfg = SolverConfig::new(algo).with_tol(1e-7).with_max_iters(200);
    let mut be = NativeBackend::new(pre.x.clone());
    let res = solve(&mut be, &Mat::eye(x.rows()), &scfg);
    println!(
        "ICA: {} iterations, final |G|inf = {:.2e}",
        res.iters,
        res.trace.last().unwrap().grad_inf
    );

    // Sources on the whitened data.
    let y = matmul(&res.w, &pre.x);
    let n = y.rows();
    let mut kurt: Vec<(usize, f64)> = (0..n).map(|i| (i, kurtosis(y.row(i)))).collect();
    kurt.sort_by(|a, b| b.1.partial_cmp(&a.1).unwrap());
    println!("top component kurtoses (blinks/artifacts are heavy-tailed):");
    for (i, k) in kurt.iter().take(5) {
        println!("  component {i:>3}: kurtosis {k:>8.2}");
    }

    // Zero every component with kurtosis > 5 (blink-like transients).
    let artifacts: Vec<usize> =
        kurt.iter().filter(|(_, k)| *k > 5.0).map(|(i, _)| *i).collect();
    println!("removing {} artifact component(s): {artifacts:?}", artifacts.len());
    assert!(!artifacts.is_empty(), "simulator always injects blinks");

    let mut y_clean = y.clone();
    for &i in &artifacts {
        y_clean.row_mut(i).fill(0.0);
    }
    // Back to channel space: X_clean = K⁻¹ · W⁻¹ · Y_clean.
    let w_inv = Lu::new(&res.w).unwrap().inverse();
    let k_inv = Lu::new(&pre.k).unwrap().inverse();
    let x_clean = matmul(&k_inv, &matmul(&w_inv, &y_clean));
    let mut x_centered = x.clone();
    x_centered.center_rows();

    // Report per-channel energy removed and the worst-case distortion of
    // a retained component.
    let energy = |m: &Mat| -> f64 { m.as_slice().iter().map(|v| v * v).sum::<f64>() };
    let removed = 1.0 - energy(&x_clean) / energy(&x_centered);
    println!("fraction of total signal energy removed: {:.1}%", removed * 100.0);
    assert!(removed > 0.005 && removed < 0.9, "implausible removal {removed}");

    // The retained sources should be untouched (linearity check).
    let y_back = matmul(&res.w, &matmul(&pre.k, &x_clean));
    let mut max_err = 0.0f64;
    for i in 0..n {
        if !artifacts.contains(&i) {
            for (a, b) in y_back.row(i).iter().zip(y_clean.row(i)) {
                max_err = max_err.max((a - b).abs());
            }
        }
    }
    println!("retained-component roundtrip error: {max_err:.2e}");
    assert!(max_err < 1e-8);
    println!("eeg_artifact_removal OK");
}

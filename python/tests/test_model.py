"""Layer-2 model graphs: shapes, numerics vs oracle, and analytic checks."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

jax.config.update("jax_enable_x64", True)

from compile import model
from compile.kernels import ref


def problem(n, t, seed=0):
    rng = np.random.default_rng(seed)
    x = jnp.asarray(rng.laplace(size=(n, t)))
    w = jnp.eye(n) + 0.05 * jnp.asarray(rng.normal(size=(n, n)))
    return w, x


class TestGraphShapes:
    def test_stats_h2(self):
        w, x = problem(5, 300)
        loss, g, h, hi, sig = model.stats_h2(w, x)
        assert loss.shape == ()
        assert g.shape == (5, 5)
        assert h.shape == (5, 5)
        assert hi.shape == (5,)
        assert sig.shape == (5,)

    def test_stats_h1(self):
        w, x = problem(4, 200)
        loss, g, hi, sig = model.stats_h1(w, x)
        assert g.shape == (4, 4) and hi.shape == (4,) and sig.shape == (4,)

    def test_stats_basic_and_grad(self):
        w, x = problem(4, 200)
        loss, g = model.stats_basic(w, x)
        (g2,) = model.grad(w, x)
        np.testing.assert_allclose(g, g2, atol=1e-15)

    def test_loss_only(self):
        w, x = problem(3, 150)
        (l1,) = model.loss_only(w, x)
        l2, _ = model.stats_basic(w, x)
        np.testing.assert_allclose(l1, l2, rtol=1e-14)


class TestGraphNumerics:
    def test_matches_oracle_on_y(self):
        w, x = problem(6, 700, seed=1)
        y = w @ x
        loss, g, h, hi, sig = model.stats_h2(w, x)
        rl, rg, rh, rhi, rsig = ref.stats_h2(y)
        np.testing.assert_allclose(loss, rl, rtol=1e-12)
        np.testing.assert_allclose(g, rg, atol=1e-12)
        np.testing.assert_allclose(h, rh, atol=1e-12)
        np.testing.assert_allclose(hi, rhi, atol=1e-12)
        np.testing.assert_allclose(sig, rsig, atol=1e-12)

    def test_gradient_is_derivative_of_loss(self):
        # <G, E> must equal d/de loss((I + eE) W) for the *full* loss;
        # our graphs omit logdet, and d/de log|det(I+eE)| = tr(E), so
        # d loss_data = <G + I_diag-part... ; directly:
        # d/de loss_data((I+eE)W) = <G + I, E> - tr(E) + tr(E) -- easier:
        # loss_data gradient is G + I - I = G + (I - I). Check against
        # finite differences of loss_data with the tr(E) correction.
        w, x = problem(4, 50_000, seed=2)
        _, g = model.stats_basic(w, x)
        rng = np.random.default_rng(3)
        e = jnp.asarray(rng.normal(size=(4, 4))) * 1.0
        eps = 1e-6
        step_p = (jnp.eye(4) + eps * e) @ w
        step_m = (jnp.eye(4) - eps * e) @ w
        (lp,) = model.loss_only(step_p, x)
        (lm,) = model.loss_only(step_m, x)
        fd = (lp - lm) / (2 * eps)
        # loss = loss_data - log|det W|; d(-log|det|)/de = -tr(E).
        # G refers to the full loss, so <G, E> = fd - tr(E).
        want = float(fd) - float(jnp.trace(e))
        got = float(jnp.sum(g * e))
        np.testing.assert_allclose(got, want, rtol=1e-5, atol=1e-7)

    def test_gaussian_integration_identity(self):
        # For Gaussian y: E[psi(y) y] = E[psi'(y)] sigma^2 (paper, sec.
        # 2.2.4, integration by parts) -- checks G and h1/sigma together.
        rng = np.random.default_rng(4)
        n, t = 3, 400_000
        x = jnp.asarray(rng.normal(size=(n, t)) * 1.7)
        w = jnp.eye(n)
        _, g, hi, sig = model.stats_h1(w, x)
        lhs = np.diag(np.asarray(g)) + 1.0  # E[psi(y_i) y_i]
        rhs = np.asarray(hi) * np.asarray(sig)
        np.testing.assert_allclose(lhs, rhs, atol=5e-3)


class TestAotLowering:
    def test_all_graphs_lower_to_hlo_text(self):
        from compile import aot

        for name in model.GRAPHS:
            text = aot.lower_graph(name, 3, 40)
            assert "HloModule" in text
            # No unservable custom-calls (LAPACK etc.) in the artifact.
            assert "custom-call" not in text, f"{name} has a custom-call"

    def test_artifact_naming(self):
        from compile import aot

        assert aot.artifact_name("stats_h2", 40, 10000) == "stats_h2_n40_t10000.hlo.txt"

    def test_manifest_generation(self, tmp_path):
        import json
        import subprocess
        import sys

        shapes = {
            "shapes": [
                {"n": 3, "t": 50, "graphs": ["loss_only"], "tag": "tmp"},
            ]
        }
        sp = tmp_path / "shapes.json"
        sp.write_text(json.dumps(shapes))
        out = tmp_path / "artifacts"
        subprocess.run(
            [sys.executable, "-m", "compile.aot", "--shapes", str(sp),
             "--out-dir", str(out)],
            check=True,
            cwd=str(os.path.dirname(os.path.dirname(__file__))),
        )
        manifest = json.loads((out / "manifest.json").read_text())
        assert manifest["dtype"] == "f64"
        assert len(manifest["artifacts"]) == 1
        art = manifest["artifacts"][0]
        assert (out / art["file"]).exists()


import os  # noqa: E402  (used in TestAotLowering)

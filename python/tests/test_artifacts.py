"""Consistency of the emitted artifact set (artifacts/ after `make
artifacts`): manifest <-> files <-> HLO parameter shapes.

These are regression tests for the Rust runtime's contract; they skip
cleanly when artifacts have not been generated yet.
"""

import json
import os
import re

import pytest

ART_DIR = os.path.join(os.path.dirname(__file__), "..", "..", "artifacts")
MANIFEST = os.path.join(ART_DIR, "manifest.json")

pytestmark = pytest.mark.skipif(
    not os.path.exists(MANIFEST), reason="run `make artifacts` first"
)


def load_manifest():
    with open(MANIFEST) as f:
        return json.load(f)


def test_manifest_is_f64():
    assert load_manifest()["dtype"] == "f64"


def test_every_entry_has_a_file():
    m = load_manifest()
    assert m["artifacts"], "manifest empty"
    for a in m["artifacts"]:
        path = os.path.join(ART_DIR, a["file"])
        assert os.path.exists(path), a["file"]
        assert os.path.getsize(path) > 100, f"{a['file']} suspiciously small"


def test_hlo_parameter_shapes_match_manifest():
    m = load_manifest()
    for a in m["artifacts"]:
        n, t = a["n"], a["t"]
        with open(os.path.join(ART_DIR, a["file"])) as f:
            text = f.read()
        assert text.startswith("HloModule"), a["file"]
        # ENTRY computation signature carries both parameter shapes.
        layout = re.search(r"entry_computation_layout=\{(.*)\}", text)
        assert layout, f"{a['file']}: no entry layout"
        sig = layout.group(1)
        assert f"f64[{n},{n}]" in sig, f"{a['file']}: W shape missing in {sig}"
        assert f"f64[{n},{t}]" in sig, f"{a['file']}: X shape missing in {sig}"


def test_no_unservable_custom_calls():
    # LAPACK/FFI custom-calls cannot be served by the xla crate's CPU
    # client (xla_extension 0.5.1); artifacts must be pure HLO.
    m = load_manifest()
    for a in m["artifacts"]:
        with open(os.path.join(ART_DIR, a["file"])) as f:
            text = f.read()
        assert "custom-call" not in text, f"{a['file']} contains a custom-call"


def test_manifest_matches_shape_registry():
    # Every (shape, graph) pair in shapes.json must be represented
    # (the Rust registry trusts the manifest; this guards aot.py drift).
    with open(
        os.path.join(os.path.dirname(__file__), "..", "compile", "shapes.json")
    ) as f:
        registry = json.load(f)
    m = load_manifest()
    have = {(a["graph"], a["n"], a["t"]) for a in m["artifacts"]}
    for entry in registry["shapes"]:
        for graph in entry["graphs"]:
            key = (graph, entry["n"], entry["t"])
            assert key in have, f"missing artifact for {key}"


def test_digests_match_files():
    import hashlib

    m = load_manifest()
    for a in m["artifacts"]:
        with open(os.path.join(ART_DIR, a["file"])) as f:
            text = f.read()
        digest = hashlib.sha256(text.encode()).hexdigest()[:16]
        assert digest == a["sha256_16"], f"{a['file']} digest drift"

"""Pallas moments kernel vs the pure-jnp oracle — the core L1 signal.

hypothesis sweeps shapes, tile sizes and dtypes; every statistic must
match `ref.py` to near-machine precision, including ragged T (padding
path) and extreme inputs (overflow-safe logcosh).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

jax.config.update("jax_enable_x64", True)

from compile.kernels import moments as mk
from compile.kernels import ref


def tol(dtype):
    return 5e-5 if dtype == jnp.float32 else 5e-13


def random_y(n, t, seed, dtype=jnp.float64, scale=1.0):
    rng = np.random.default_rng(seed)
    return jnp.asarray(rng.laplace(size=(n, t)) * scale, dtype=dtype)


def assert_matches(y, tb=None, level=mk.LEVEL_H2):
    eps = tol(y.dtype)
    loss, g, h, hi, sig = mk.moments(y, tb=tb, level=level)
    rl, rg, rh, rhi, rsig = ref.stats_h2(y)
    np.testing.assert_allclose(loss, rl, atol=eps, rtol=eps)
    np.testing.assert_allclose(g, rg, atol=eps, rtol=eps)
    if level in (mk.LEVEL_H1, mk.LEVEL_H2):
        np.testing.assert_allclose(hi, rhi, atol=eps, rtol=eps)
        np.testing.assert_allclose(sig, rsig, atol=eps, rtol=eps)
    else:
        assert hi is None and sig is None
    if level == mk.LEVEL_H2:
        np.testing.assert_allclose(h, rh, atol=eps, rtol=eps)
    else:
        assert h is None


class TestMomentsBasics:
    def test_divisible_tiles(self):
        assert_matches(random_y(5, 512, 0), tb=128)

    def test_ragged_tail_masked(self):
        # 700 = 5*128 + 60: exercises zero-padding and the psi' mask.
        assert_matches(random_y(5, 700, 1), tb=128)

    def test_single_tile(self):
        assert_matches(random_y(3, 64, 2), tb=64)

    def test_t_smaller_than_tb(self):
        assert_matches(random_y(4, 50, 3), tb=128)

    def test_level_basic(self):
        assert_matches(random_y(4, 300, 4), tb=128, level=mk.LEVEL_BASIC)

    def test_level_h1(self):
        assert_matches(random_y(4, 300, 5), tb=128, level=mk.LEVEL_H1)

    def test_large_values_no_overflow(self):
        y = random_y(3, 256, 6, scale=500.0)
        loss, g, *_ = mk.moments(y, tb=128)
        assert np.isfinite(float(loss))
        assert np.all(np.isfinite(np.asarray(g)))
        rl = ref.loss_data(y)
        np.testing.assert_allclose(loss, rl, rtol=1e-12)

    def test_float32(self):
        assert_matches(random_y(4, 256, 7, dtype=jnp.float32), tb=128)

    def test_gradient_small_near_laplace_optimum(self):
        # Independent unit-RMS Laplace rows are close to a stationary
        # point of the logcosh loss up to per-row scale: off-diagonal G
        # entries must vanish statistically (diagonal reflects the scale
        # mismatch between the Laplace and logcosh models).
        y = random_y(4, 100_000, 8)
        y = y / jnp.std(y, axis=1, keepdims=True)
        _, g, *_ = mk.moments(y)
        g = np.asarray(g)
        off = g - np.diag(np.diag(g))
        assert np.all(np.abs(off) < 0.02), off


class TestLossKernel:
    def test_matches_ref(self):
        y = random_y(6, 700, 10)
        got = mk.loss_only(y, tb=128)
        np.testing.assert_allclose(got, ref.loss_data(y), rtol=1e-13)

    def test_zero_input(self):
        y = jnp.zeros((3, 200))
        assert float(mk.loss_only(y, tb=64)) == 0.0


class TestPickTb:
    def test_power_of_two_and_bounded(self):
        for n in [4, 40, 64, 128]:
            for t in [500, 10_000, 300_000]:
                tb = mk.pick_tb(n, t)
                assert tb & (tb - 1) == 0
                assert tb >= 1

    def test_vmem_budget_respected(self):
        for n in [8, 64, 256]:
            rep = mk.vmem_report(n, 100_000)
            assert rep["vmem_bytes"] <= 4 * 1024 * 1024 + (2 * n * n + 3 * n) * 8

    def test_mxu_dominates_for_large_n(self):
        rep = mk.vmem_report(64, 30_000)
        assert rep["mxu_fraction"] > 0.8


@settings(max_examples=25, deadline=None)
@given(
    n=st.integers(1, 12),
    t=st.integers(2, 600),
    tb_exp=st.integers(5, 9),
    seed=st.integers(0, 2**31),
)
def test_hypothesis_shape_sweep(n, t, tb_exp, seed):
    y = random_y(n, t, seed)
    assert_matches(y, tb=2**tb_exp)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 8),
    t=st.integers(2, 400),
    seed=st.integers(0, 2**31),
    level=st.sampled_from([mk.LEVEL_BASIC, mk.LEVEL_H1, mk.LEVEL_H2]),
)
def test_hypothesis_level_sweep(n, t, seed, level):
    assert_matches(random_y(n, t, seed), tb=128, level=level)


@settings(max_examples=10, deadline=None)
@given(
    n=st.integers(1, 6),
    t=st.integers(2, 300),
    seed=st.integers(0, 2**31),
    dtype=st.sampled_from([jnp.float32, jnp.float64]),
)
def test_hypothesis_dtype_sweep(n, t, seed, dtype):
    assert_matches(random_y(n, t, seed, dtype=dtype), tb=128)

"""Pure-jnp oracle for the ICA per-iteration statistics (Layer-1 reference).

These are the textbook formulas from the paper, written with no fusion or
tiling tricks. The Pallas kernels in `moments.py` must match these to
near-machine precision; pytest + hypothesis enforce it.

Quantities (paper eqs. 2-4), for Y in R^{N x T}:

    loss_data = E[sum_i 2 log cosh(y_i/2)]          (data term of eq. 2)
    G         = E[psi(Y) Y^T] - I, psi = tanh(./2)  (eq. 3)
    h_ij      = E[psi'(y_i) y_j^2]                  (eq. 4)
    h_i       = E[psi'(y_i)]                        (eq. 4)
    sigma_j^2 = E[y_j^2]                            (eq. 4)
"""

import jax.numpy as jnp

LN2 = 0.6931471805599453


def neg_log_density(y):
    """2 log cosh(y/2), computed overflow-safely."""
    a = jnp.abs(0.5 * y)
    return 2.0 * (a + jnp.log1p(jnp.exp(-2.0 * a)) - LN2)


def psi(y):
    """Score function tanh(y/2)."""
    return jnp.tanh(0.5 * y)


def psi_prime(y):
    """psi'(y) = (1 - tanh^2(y/2)) / 2."""
    t = jnp.tanh(0.5 * y)
    return 0.5 * (1.0 - t * t)


def loss_data(y):
    """Per-sample averaged data loss."""
    t = y.shape[1]
    return jnp.sum(neg_log_density(y)) / t


def gradient(y):
    """Relative gradient G = psi(Y) Y^T / T - I."""
    n, t = y.shape
    return psi(y) @ y.T / t - jnp.eye(n, dtype=y.dtype)


def h2_moments(y):
    """h_ij = psi'(Y) (Y*Y)^T / T."""
    t = y.shape[1]
    return psi_prime(y) @ (y * y).T / t


def h1_moments(y):
    """(h_i, sigma_j^2)."""
    return jnp.mean(psi_prime(y), axis=1), jnp.mean(y * y, axis=1)


def stats_h2(y):
    """Full statistics tuple: (loss_data, G, h_ij, h_i, sigma^2)."""
    hi, sig = h1_moments(y)
    return loss_data(y), gradient(y), h2_moments(y), hi, sig


def stats_h1(y):
    """Theta(NT)-moment statistics: (loss_data, G, h_i, sigma^2)."""
    hi, sig = h1_moments(y)
    return loss_data(y), gradient(y), hi, sig

"""Layer-1 Pallas kernel: fused single-sweep ICA moments.

The Theta(N^2 T) per-iteration hot spot of the paper. One sweep over the
sample axis of Y = WX produces, per T-tile held in VMEM:

    psi  = tanh(y/2)            -> G partial    psi @ y^T      (MXU matmul)
    psi' = (1 - psi^2)/2        -> h_ij partial psi' @ (y*y)^T (MXU matmul)
    logcosh loss partial, h_i partial, sigma^2 partial         (VPU reduce)

The tanh is evaluated exactly once per element and feeds every statistic
— the same cache-blocking idea the paper implements with numexpr/MKL, here
expressed as a BlockSpec over the T axis: `grid=(T/TB,)`, the Y tile
`(N, TB)` streams HBM->VMEM while the (N,N)/(N,1) accumulators stay
resident across grid steps (Pallas keeps same-index output blocks in VMEM,
so `ref[...] +=` accumulates without HBM round-trips).

TPU adaptation notes (DESIGN.md "Hardware adaptation"): the two rank-TB
contractions map onto the MXU; everything else is elementwise VPU work on
the same tile. VMEM budget per step = (3 tiles of N x TB + accumulators)
* 8 bytes; TB is chosen by `pick_tb` to stay under ~4 MiB so double
buffering fits in 16 MiB VMEM. interpret=True everywhere on CPU — the
structure, not the wallclock, is what carries to real TPUs.

Padding: T may not be a multiple of TB. The final tile is zero-padded by
the caller; zeros are harmless for loss/G/h_ij/sigma^2 (psi(0)=0, y^2=0)
but psi'(0)=1/2 would pollute h_i, so the kernel masks psi' with the
global column index (static T_real baked in at trace time).
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

LN2 = 0.6931471805599453

# Statistics levels, mirroring rust's backend::StatsLevel.
LEVEL_BASIC = "basic"  # loss + G
LEVEL_H1 = "h1"        # + h_i, sigma^2
LEVEL_H2 = "h2"        # + h_ij


def pick_tb(n, t, vmem_bytes=4 * 1024 * 1024, dtype_bytes=8):
    """Largest power-of-two tile size TB such that the working set
    (three N x TB tiles + the N x N / N-vector accumulators) fits the
    VMEM budget, clamped to [128, t]."""
    acc = (2 * n * n + 3 * n) * dtype_bytes
    tb = 128
    while True:
        nxt = tb * 2
        if nxt > t or 3 * n * nxt * dtype_bytes + acc > vmem_bytes:
            break
        tb = nxt
    return min(tb, max(t, 1))


def _moments_kernel(y_ref, g_ref, h_ref, hi_ref, sig_ref, loss_ref, *,
                    t_real, tb, level):
    """One grid step: consume a (N, TB) tile of Y, update accumulators."""
    y = y_ref[...]
    u = 0.5 * y
    a = jnp.abs(u)
    psi = jnp.tanh(u)

    step = pl.program_id(0)

    @pl.when(step == 0)
    def _init():
        g_ref[...] = jnp.zeros_like(g_ref)
        loss_ref[...] = jnp.zeros_like(loss_ref)
        if level in (LEVEL_H1, LEVEL_H2):
            hi_ref[...] = jnp.zeros_like(hi_ref)
            sig_ref[...] = jnp.zeros_like(sig_ref)
        if level == LEVEL_H2:
            h_ref[...] = jnp.zeros_like(h_ref)

    # Column mask: global sample index < T (zero-padding guard).
    col = step * tb + jax.lax.broadcasted_iota(jnp.int32, y.shape, 1)
    valid = col < t_real

    loss_tile = jnp.sum(2.0 * (a + jnp.log1p(jnp.exp(-2.0 * a)) - LN2))
    loss_ref[...] += loss_tile

    # G partial: psi @ y^T. Padded columns contribute psi(0)*0 = 0.
    g_ref[...] += jax.lax.dot_general(
        psi, y, (((1,), (1,)), ((), ())),
        preferred_element_type=y.dtype)

    if level in (LEVEL_H1, LEVEL_H2):
        psip = jnp.where(valid, 0.5 * (1.0 - psi * psi), 0.0)
        ysq = y * y
        hi_ref[...] += jnp.sum(psip, axis=1)
        sig_ref[...] += jnp.sum(ysq, axis=1)
        if level == LEVEL_H2:
            h_ref[...] += jax.lax.dot_general(
                psip, ysq, (((1,), (1,)), ((), ())),
                preferred_element_type=y.dtype)


def moments(y, t_real=None, tb=None, level=LEVEL_H2, interpret=True):
    """Fused ICA moments of Y (already padded to a TB multiple by the
    caller, or padded here if needed).

    Returns (loss_data, G, h_ij, h_i, sigma^2) with the trailing entries
    present per `level` (absent ones are None). All are *averaged* over
    t_real samples and G has the identity subtracted.
    """
    n, t_pad = y.shape
    if t_real is None:
        t_real = t_pad
    if tb is None:
        tb = pick_tb(n, t_pad)
    if t_pad % tb:
        pad = tb - t_pad % tb
        y = jnp.pad(y, ((0, 0), (0, pad)))
        t_pad += pad
    grid = (t_pad // tb,)
    dtype = y.dtype

    out_shapes = (
        jax.ShapeDtypeStruct((n, n), dtype),   # g sum
        jax.ShapeDtypeStruct((n, n), dtype),   # h sum
        jax.ShapeDtypeStruct((n,), dtype),     # hi sum
        jax.ShapeDtypeStruct((n,), dtype),     # sig sum
        jax.ShapeDtypeStruct((), dtype),       # loss sum
    )
    # Accumulators live at block (0, 0) for every grid step.
    out_specs = (
        pl.BlockSpec((n, n), lambda i: (0, 0)),
        pl.BlockSpec((n, n), lambda i: (0, 0)),
        pl.BlockSpec((n,), lambda i: (0,)),
        pl.BlockSpec((n,), lambda i: (0,)),
        pl.BlockSpec((), lambda i: ()),
    )
    kernel = functools.partial(
        _moments_kernel, t_real=t_real, tb=tb, level=level)
    gsum, hsum, hisum, sigsum, losssum = pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((n, tb), lambda i: (0, i))],
        out_shape=out_shapes,
        out_specs=out_specs,
        interpret=interpret,
    )(y)

    inv_t = 1.0 / t_real
    loss = losssum * inv_t
    g = gsum * inv_t - jnp.eye(n, dtype=dtype)
    h = hsum * inv_t if level == LEVEL_H2 else None
    hi = hisum * inv_t if level in (LEVEL_H1, LEVEL_H2) else None
    sig = sigsum * inv_t if level in (LEVEL_H1, LEVEL_H2) else None
    return loss, g, h, hi, sig


def _loss_kernel(y_ref, loss_ref):
    """Loss-only sweep (line-search probe): no psi, no matmuls."""
    y = y_ref[...]
    a = jnp.abs(0.5 * y)

    @pl.when(pl.program_id(0) == 0)
    def _init():
        loss_ref[...] = jnp.zeros_like(loss_ref)

    loss_ref[...] += jnp.sum(2.0 * (a + jnp.log1p(jnp.exp(-2.0 * a)) - LN2))


def loss_only(y, t_real=None, tb=None, interpret=True):
    """Data-part loss of Y through the Pallas loss kernel."""
    n, t_pad = y.shape
    if t_real is None:
        t_real = t_pad
    if tb is None:
        tb = pick_tb(n, t_pad)
    if t_pad % tb:
        pad = tb - t_pad % tb
        y = jnp.pad(y, ((0, 0), (0, pad)))
        t_pad += pad
    losssum = pl.pallas_call(
        _loss_kernel,
        grid=(t_pad // tb,),
        in_specs=[pl.BlockSpec((n, tb), lambda i: (0, i))],
        out_shape=jax.ShapeDtypeStruct((), y.dtype),
        out_specs=pl.BlockSpec((), lambda i: ()),
        interpret=interpret,
    )(y)
    return losssum / t_real


def vmem_report(n, t, tb=None, dtype_bytes=8):
    """Estimated VMEM working set + MXU utilization for DESIGN.md Perf.

    Returns a dict with the per-grid-step VMEM bytes and the fraction of
    kernel FLOPs that land on the MXU (the two rank-TB contractions)
    versus the VPU (elementwise tanh/log1p sweeps).
    """
    if tb is None:
        tb = pick_tb(n, t)
    tiles = 3 * n * tb * dtype_bytes          # y, psi/psip, ysq
    accs = (2 * n * n + 3 * n) * dtype_bytes
    mxu_flops = 2 * 2 * n * n * tb            # two (N,TB)x(TB,N) matmuls
    # elementwise: tanh(~10 flop-equiv), log1p/exp(~10), squares/sums (~6)
    vpu_flops = 26 * n * tb
    return {
        "tb": tb,
        "vmem_bytes": tiles + accs,
        "mxu_fraction": mxu_flops / (mxu_flops + vpu_flops),
        "flops_per_tile": mxu_flops + vpu_flops,
    }

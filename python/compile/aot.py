"""AOT lowering: JAX graphs -> HLO *text* artifacts for the Rust runtime.

HLO text (not `lowered.compile()` / serialized HloModuleProto) is the
interchange format: jax >= 0.5 emits protos with 64-bit instruction ids
which xla_extension 0.5.1 (behind the published `xla` crate 0.1.6)
rejects; the text parser reassigns ids and round-trips cleanly. See
/opt/xla-example/gen_hlo.py and its README.

Usage (from the repo's python/ directory, as the Makefile does):

    python -m compile.aot --out-dir ../artifacts [--shapes compile/shapes.json]

Emits one `<graph>_n{N}_t{T}.hlo.txt` per (shape, graph) pair plus a
`manifest.json` the Rust artifact registry loads.
"""

import argparse
import hashlib
import json
import os
import sys

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from . import model


def to_hlo_text(lowered):
    """Lowered JAX computation -> HLO text.

    `compiler_ir(dialect="hlo")` hands back the XlaComputation directly;
    the StableHLO-text route (mlir_module_to_xla_computation) breaks on
    version skew between jax's emitted StableHLO and the converter's
    parser (e.g. `stablehlo.dynamic_slice` attribute renames), so we stay
    in HLO land end-to-end. Multi-output graphs get a tuple root, single
    outputs stay bare — the Rust loader handles both.
    """
    return lowered.compiler_ir(dialect="hlo").as_hlo_text()


def lower_graph(name, n, t):
    fn = model.GRAPHS[name]
    w = jax.ShapeDtypeStruct((n, n), jnp.float64)
    x = jax.ShapeDtypeStruct((n, t), jnp.float64)
    return to_hlo_text(jax.jit(fn).lower(w, x))


def artifact_name(graph, n, t):
    return f"{graph}_n{n}_t{t}.hlo.txt"


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--shapes",
        default=os.path.join(os.path.dirname(__file__), "shapes.json"),
    )
    ap.add_argument("--only-tag", default=None,
                    help="restrict to shapes with this tag (faster CI)")
    args = ap.parse_args()

    with open(args.shapes) as f:
        registry = json.load(f)

    os.makedirs(args.out_dir, exist_ok=True)
    manifest = {"dtype": "f64", "artifacts": []}
    total = 0
    for entry in registry["shapes"]:
        if args.only_tag and entry.get("tag") != args.only_tag:
            continue
        n, t = entry["n"], entry["t"]
        for graph in entry["graphs"]:
            fname = artifact_name(graph, n, t)
            path = os.path.join(args.out_dir, fname)
            text = lower_graph(graph, n, t)
            with open(path, "w") as f:
                f.write(text)
            digest = hashlib.sha256(text.encode()).hexdigest()[:16]
            manifest["artifacts"].append(
                {
                    "graph": graph,
                    "n": n,
                    "t": t,
                    "file": fname,
                    "sha256_16": digest,
                    "tag": entry.get("tag", ""),
                }
            )
            total += 1
            print(f"  wrote {fname} ({len(text)} chars)", file=sys.stderr)

    with open(os.path.join(args.out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=2)
    print(f"AOT: {total} artifacts -> {args.out_dir}", file=sys.stderr)


if __name__ == "__main__":
    main()

"""Layer-2 JAX model: the compute graphs the Rust coordinator executes.

Each graph takes the unmixing matrix W and the (preprocessed) data X,
forms Y = W @ X (one MXU matmul) and feeds the fused Pallas moments
kernel. Everything is f64 — convergence to gradient-inf-norm 1e-8 and the
quadratic tail of the quasi-Newton methods need it.

`log|det W|` is deliberately NOT in these graphs: on the CPU PJRT plugin
of xla_extension 0.5.1 it would lower to a LAPACK custom-call that the
runtime cannot serve. Rust adds it with its own LU (Theta(N^3), trivial
next to the Theta(N^2 T) sweeps here).

Graphs (all return flat tuples, lowered with return_tuple=True):

    stats_h2(w, x)  -> (loss_data, G, h_ij, h_i, sigma2)
    stats_h1(w, x)  -> (loss_data, G, h_i, sigma2)
    stats_basic(w,x)-> (loss_data, G)
    loss_only(w, x) -> (loss_data,)
    grad(w, x)      -> (G,)          # Infomax minibatch step
"""

import jax
import jax.numpy as jnp

jax.config.update("jax_enable_x64", True)

from .kernels import moments as mk


def _y(w, x):
    return jnp.dot(w, x, preferred_element_type=x.dtype)


def stats_h2(w, x):
    loss, g, h, hi, sig = mk.moments(_y(w, x), level=mk.LEVEL_H2)
    return loss, g, h, hi, sig


def stats_h1(w, x):
    loss, g, _, hi, sig = mk.moments(_y(w, x), level=mk.LEVEL_H1)
    return loss, g, hi, sig


def stats_basic(w, x):
    loss, g, _, _, _ = mk.moments(_y(w, x), level=mk.LEVEL_BASIC)
    return loss, g


def loss_only(w, x):
    return (mk.loss_only(_y(w, x)),)


def grad(w, x):
    _, g, _, _, _ = mk.moments(_y(w, x), level=mk.LEVEL_BASIC)
    return (g,)


#: name -> (callable, which outputs it produces); single source of truth
#: for aot.py and the tests.
GRAPHS = {
    "stats_h2": stats_h2,
    "stats_h1": stats_h1,
    "stats_basic": stats_basic,
    "loss_only": loss_only,
    "grad": grad,
}
